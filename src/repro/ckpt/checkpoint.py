"""Sharded npz checkpoints with async save and ELASTIC restore.

- save_checkpoint: flattens the (params, opt_state, step, meta) pytree to
  path-keyed arrays; writes atomically (tmp + rename); optional async
  (background thread) so the train loop never blocks on IO.
- restore_checkpoint: rebuilds the pytree; `mesh`/`specs` may describe a
  DIFFERENT device topology than the one that saved — arrays are
  device_put with the new sharding (GSPMD global arrays make elastic
  re-sharding a plain relayout).  This is the checkpoint/restart +
  elastic-scaling substrate.
"""

from __future__ import annotations

import json
import os
import threading
from typing import Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]

_SEP = "##"


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree, meta: Optional[dict]
                    = None, async_save: bool = False):
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)          # host copy happens synchronously

    def _write():
        tmp = os.path.join(ckpt_dir, f".tmp-{step}.npz")
        final = os.path.join(ckpt_dir, f"step-{step:08d}.npz")
        np.savez(tmp, **flat)
        os.replace(tmp, final)
        with open(os.path.join(ckpt_dir, f"step-{step:08d}.json"),
                  "w") as f:
            json.dump(dict(step=step, **(meta or {})), f)

    if async_save:
        t = threading.Thread(target=_write, daemon=False)
        t.start()
        return t
    _write()
    return None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(f[5:13]) for f in os.listdir(ckpt_dir)
             if f.startswith("step-") and f.endswith(".npz")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like_tree, mesh=None,
                       specs=None):
    """like_tree provides the structure; mesh+specs (optional) re-shard
    onto a possibly different topology (elastic restore)."""
    path = os.path.join(ckpt_dir, f"step-{step:08d}.npz")
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    leaves = []
    for p, like in paths:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in p)
        arr = data[key]
        assert arr.shape == tuple(like.shape), (key, arr.shape, like.shape)
        leaves.append(arr.astype(like.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if mesh is not None and specs is not None:
        from jax.sharding import NamedSharding
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            tree, specs)
    return tree
