"""Slim Fly reproduction framework.

Besides marking the package root, this module carries small
forward-compat shims so the codebase is written against the CURRENT
jax API surface while still running on the pinned toolchain image
(jax 0.4.x): ``jax.shard_map`` graduated from
``jax.experimental.shard_map`` (keyword ``check_rep`` became
``check_vma``); we alias it when missing.  No behaviour changes on
newer jax where the attribute already exists.
"""

import jax as _jax

if not hasattr(_jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map

    def _compat_shard_map(f, mesh, in_specs, out_specs, check_vma=True,
                          **kwargs):
        kwargs.pop("check_rep", None)
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)

    _jax.shard_map = _compat_shard_map
