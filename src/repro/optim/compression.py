"""Error-feedback int8 gradient compression for the data-parallel
all-reduce (distributed-optimization trick, cf. system spec).

Wire format is int8 (4x fewer bytes than f32 / 2x fewer than bf16): the
all-reduce is decomposed into reduce-scatter + all-gather where every
transfer is int8; partial sums are accumulated in f32 locally between the
two phases.  The quantization residual is carried in an error-feedback
buffer so the compression bias vanishes over steps (EF-SGD).

Usage (inside shard_map over the data axis):
    g_hat, new_err = compressed_psum(g + err, axis="data")
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["compressed_psum", "init_error_buffer"]


def init_error_buffer(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(g: jax.Array, axis: str):
    """Mean-all-reduce of g over `axis` with int8 wire traffic.

    g: f32 array whose leading dim is divisible by the axis size (pad
    upstream).  Returns (g_mean, local_error) where local_error is the
    quantization residual to fold into the next step's gradient.
    """
    n = lax.psum(1, axis)
    orig_shape = g.shape
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))

    # ---- phase 1: reduce-scatter in int8
    q, scale = _quant(flat)
    err = flat - q.astype(jnp.float32) * scale          # local residual
    chunks = q.reshape(n, -1)                           # [n, C] int8 wire
    # all_to_all: device i receives chunk i from every peer
    recv = lax.all_to_all(chunks, axis, split_axis=0, concat_axis=0,
                          tiled=False)                  # [n, C] int8
    scales = lax.all_gather(scale, axis)                # [n] f32 (tiny)
    partial = jnp.sum(recv.astype(jnp.float32)
                      * scales[:, None], axis=0)        # f32 accumulate

    # ---- phase 2: all-gather the re-quantized partial sums (int8 wire)
    q2, scale2 = _quant(partial)
    err2 = partial - q2.astype(jnp.float32) * scale2
    gq = lax.all_gather(q2, axis)                       # [n, C] int8
    gs = lax.all_gather(scale2, axis)
    summed = (gq.astype(jnp.float32) * gs[:, None]).reshape(-1)

    out = summed[: g.size].reshape(orig_shape) / n

    # Error feedback: the phase-1 residual is local (same units as g); the
    # phase-2 residual (err2) belongs to this device's reduced shard — add
    # it back at this device's chunk offset so the owner re-injects it.
    idx = lax.axis_index(axis)
    chunk_len = err2.shape[0]
    owned = lax.dynamic_slice(err, (idx * chunk_len,), (chunk_len,)) + err2
    err_flat = lax.dynamic_update_slice(err, owned, (idx * chunk_len,))
    local_err = err_flat[: g.size].reshape(orig_shape)
    return out, local_err
