"""AdamW with global-norm clipping and optional int8-quantized moments
(blockwise scales) — the optimizer-state trick that lets the 400B
llama4-maverick config fit a 256-chip pod (EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update",
           "quantize_blockwise", "dequantize_blockwise"]

_BLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    quantized_state: bool = False     # int8 m/v with blockwise scales
    state_dtype: jnp.dtype = jnp.float32


def lr_schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr_peak * warm * (0.1 + 0.9 * cos)


# ----------------------------------------------------- int8 block quant --
def quantize_blockwise(x):
    """x [*shape] -> (int8 values, f32 scales per 128-block of the last
    axis).  Lossy; used for optimizer moments."""
    orig_shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), orig_shape


def dequantize_blockwise(q, scale, orig_shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    size = 1
    for d in orig_shape:
        size *= d
    return flat[:size].reshape(orig_shape)


# ------------------------------------------------------------- optimizer --
def init_opt_state(params, cfg: AdamWConfig):
    def zeros_like_state(p):
        if cfg.quantized_state:
            q, s, shp = quantize_blockwise(jnp.zeros_like(p, jnp.float32))
            return dict(q=q, scale=s)
        return jnp.zeros(p.shape, cfg.state_dtype)

    return dict(
        m=jax.tree.map(zeros_like_state, params),
        v=jax.tree.map(zeros_like_state, params),
        step=jnp.zeros((), jnp.int32),
    )


def _read_state(st, like):
    if isinstance(st, dict):
        return dequantize_blockwise(st["q"], st["scale"], like.shape)
    return st.astype(jnp.float32)


def _write_state(val, quantized, dtype):
    if quantized:
        q, s, _ = quantize_blockwise(val)
        return dict(q=q, scale=s)
    return val.astype(dtype)


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(step, cfg)

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m_st, v_st):
        g = g.astype(jnp.float32) * clip
        m = _read_state(m_st, p)
        v = _read_state(v_st, p)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        update = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return (newp,
                _write_state(m, cfg.quantized_state, cfg.state_dtype),
                _write_state(v, cfg.quantized_state, cfg.state_dtype))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return (new_params,
            dict(m=new_m, v=new_v, step=step),
            dict(grad_norm=gnorm, lr=lr))
