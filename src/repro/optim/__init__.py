"""Optimizers: AdamW (+int8 states), EF-int8 gradient compression."""

from .adamw import AdamWConfig, adamw_update, init_opt_state
from .compression import compressed_psum, init_error_buffer

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state",
           "compressed_psum", "init_error_buffer"]
