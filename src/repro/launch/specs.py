"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell:
weak-type-correct, shardable, zero device allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import SHAPES, ModelConfig, ShapeSpec, get
from ..dist.sharding import (batch_spec, cache_specs, data_axes,
                             param_specs, sanitize_spec)
from ..models import model as M
from ..optim.adamw import AdamWConfig, init_opt_state

__all__ = ["input_specs", "params_struct", "opt_struct", "cache_struct",
           "train_step_fn", "prefill_fn", "decode_fn", "opt_config_for"]


def _sds(shape, dtype, mesh=None, spec=None):
    sharding = NamedSharding(mesh, spec) if mesh is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _tree_sds(shapes_tree, mesh, specs_tree, dtype):
    def mk(shape, spec):
        return _sds(tuple(shape), dtype, mesh, spec)
    return jax.tree.map(
        mk, shapes_tree, specs_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(i, (int, np.integer)) for i in x))


def params_struct(cfg: ModelConfig, mesh: Mesh, dtype=jnp.bfloat16,
                  fsdp: bool = False):
    shapes = M.param_shapes(cfg)
    specs = param_specs(shapes, mesh, fsdp=fsdp)
    return _tree_sds(shapes, mesh, specs, dtype)


def opt_config_for(cfg: ModelConfig) -> AdamWConfig:
    """llama4-maverick (400B) needs int8 moments to fit a 256-chip pod;
    everyone else runs f32 moments."""
    if cfg.name.startswith("llama4"):
        return AdamWConfig(quantized_state=True)
    return AdamWConfig()


def opt_struct(params_sds, opt_cfg: AdamWConfig, mesh: Mesh):
    """eval_shape the optimizer init, then re-attach shardings: f32 moments
    shard exactly like their parameter; int8-quantized blocks [Nb, 128]
    shard the block dim over every mesh axis that divides it (they are
    flat — parameter structure is irrelevant)."""
    out = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), params_sds)

    if not opt_cfg.quantized_state:
        def attach(path_sds, like_sds):
            return jax.ShapeDtypeStruct(path_sds.shape, path_sds.dtype,
                                        sharding=like_sds.sharding)
        m = jax.tree.map(attach, out["m"], params_sds)
        v = jax.tree.map(attach, out["v"], params_sds)
        return dict(m=m, v=v, step=out["step"])

    all_axes = tuple(mesh.axis_names)

    def attach_q(sds):
        spec = sanitize_spec(tuple(sds.shape),
                             P(all_axes, *([None] * (len(sds.shape) - 1))),
                             mesh)
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=NamedSharding(mesh, spec))

    m = jax.tree.map(attach_q, out["m"])
    v = jax.tree.map(attach_q, out["v"])
    return dict(m=m, v=v, step=out["step"])


def input_specs(arch: str, shape_name: str, mesh: Mesh):
    """Model inputs for a cell: tokens/labels (+ frontend stubs)."""
    cfg = get(arch)
    shape = SHAPES[shape_name]
    bsp = batch_spec(mesh)
    B = shape.global_batch

    tok_shape = (B, 1) if shape.kind == "decode" else (B, shape.seq_len)
    toks = _sds(tok_shape, jnp.int32, mesh,
                sanitize_spec(tok_shape, bsp, mesh))
    batch = dict(tokens=toks)
    if shape.kind == "train":
        batch["labels"] = toks

    stub_shape = (B, cfg.n_frontend_tokens, cfg.d_model)
    stub_spec = sanitize_spec(stub_shape, P(bsp[0], None, "model"), mesh)
    if cfg.frontend == "vision_stub" and shape.kind != "decode":
        batch["patches"] = _sds(stub_shape, jnp.bfloat16, mesh, stub_spec)
    if cfg.frontend == "audio_stub":
        batch["frames"] = _sds(stub_shape, jnp.bfloat16, mesh, stub_spec)
    return batch


def cache_struct(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                 dtype=jnp.bfloat16, seq_shard_kv: bool | None = None):
    """Decode-cache ShapeDtypeStructs (incl. whisper cross-KV)."""
    if seq_shard_kv is None:
        tp = mesh.devices.shape[-1]
        seq_shard_kv = (cfg.n_kv_heads % tp) != 0
    B = shape.global_batch
    out = jax.eval_shape(
        lambda: M.init_cache(cfg, B, max_len=shape.seq_len, dtype=dtype))
    if cfg.n_encoder_layers:
        Hkv, Dh, F = cfg.n_kv_heads, cfg.hd, cfg.n_frontend_tokens
        kv = jax.ShapeDtypeStruct((B, F, Hkv, Dh), dtype)
        out["cross_kv"] = [(kv, kv) for _ in range(cfg.n_layers)]
    specs = cache_specs(mesh, out, seq_shard_kv=seq_shard_kv)
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        out, specs)


# ---- step functions (what gets lowered) -----------------------------------
def train_step_fn(cfg: ModelConfig, opt_cfg: AdamWConfig,
                  microbatches: int = 1, remat: str = "dots_saveable"):
    from ..train.loop import TrainConfig, make_train_step
    tc = TrainConfig(microbatches=microbatches, remat=remat)
    return make_train_step(cfg, opt_cfg, tc)


def prefill_fn(cfg: ModelConfig):
    """Serving prefill: full forward, last-position logits only."""
    def fn(params, batch):
        logits = M.forward(params, batch, cfg)
        return logits[:, -1:]
    return fn


def decode_fn(cfg: ModelConfig):
    def fn(params, tokens, cache):
        return M.decode_step(params, tokens, cfg, cache)
    return fn
