import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import (jax locks the device count on first init).

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) cell, lower + compile the step on
the production mesh (16x16 single-pod and 2x16x16 multi-pod), print
memory_analysis() (fits?) and cost_analysis() (FLOPs/bytes for the
roofline), and dump a json row consumed by benchmarks/roofline_bench.py
and EXPERIMENTS.md.

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b \
      --shape train_4k [--multi-pod] [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES, get
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as S
from repro.models import model as M
from repro.utils.roofline import model_flops, roofline_from_compiled

SKIP = "SKIP"


def cell_supported(arch: str, shape_name: str) -> bool:
    cfg = get(arch)
    if shape_name == "long_500k" and not cfg.supports_long:
        return False           # pure full-attention archs (DESIGN.md §4)
    return True


def active_params(cfg) -> int:
    """Active params for MoE MODEL_FLOPS (6 N_active D)."""
    shapes = M.param_shapes(cfg)
    total = sum(int(np.prod(s)) for s in jax.tree.leaves(
        shapes, is_leaf=lambda x: isinstance(x, tuple)))
    if not cfg.n_experts:
        return total
    moe_layers = sum(1 for s in cfg.layer_kinds() if s["ffn"] == "moe")
    per_expert = 3 * cfg.d_model * cfg.d_ff
    inactive = moe_layers * per_expert * (cfg.n_experts - cfg.top_k)
    return total - inactive


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             microbatches: int = 1, remat: str = "none",
             fsdp: bool = True, scan_layers: bool = True) -> dict:
    import dataclasses
    cfg = get(arch)
    if scan_layers and not cfg.n_encoder_layers:
        # scan-over-layers: O(1)-in-depth HLO + the scan unit carries the
        # dots_saveable remat policy (so remat arg stays "none")
        cfg = dataclasses.replace(cfg, scan_layers=True)
    mesh = make_production_mesh(multi_pod=multi_pod)
    from repro.dist.sharding import data_axes
    tp_size = mesh.devices.shape[-1]
    cfg = dataclasses.replace(
        cfg, dp_axes=data_axes(mesh), tp_axis="model",
        attn_seq_shard=(cfg.n_kv_heads % tp_size) != 0,
        moe_ep=(cfg.n_experts % tp_size == 0) if cfg.n_experts else None,
        moe_groups=(1 if (cfg.n_experts and cfg.n_experts % tp_size == 0)
                    else int(np.prod(mesh.devices.shape[:-1]))))
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = int(np.prod(mesh.devices.shape))
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            opt_cfg = S.opt_config_for(cfg)
            params = S.params_struct(cfg, mesh, jnp.bfloat16, fsdp=fsdp)
            opt = S.opt_struct(params, opt_cfg, mesh)
            batch = S.input_specs(arch, shape_name, mesh)
            step = S.train_step_fn(cfg, opt_cfg, microbatches, remat)
            lowered = jax.jit(step).lower(params, opt, batch)
        elif shape.kind == "prefill":
            params = S.params_struct(cfg, mesh, jnp.bfloat16)
            batch = S.input_specs(arch, shape_name, mesh)
            lowered = jax.jit(S.prefill_fn(cfg)).lower(params, batch)
        else:  # decode
            params = S.params_struct(cfg, mesh, jnp.bfloat16)
            batch = S.input_specs(arch, shape_name, mesh)
            cache = S.cache_struct(cfg, shape, mesh)
            lowered = jax.jit(S.decode_fn(cfg)).lower(
                params, batch["tokens"], cache)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    n_total = sum(int(np.prod(s)) for s in jax.tree.leaves(
        M.param_shapes(cfg), is_leaf=lambda x: isinstance(x, tuple)))
    mf = model_flops(cfg, shape, n_total, active_params(cfg))
    terms = roofline_from_compiled(
        compiled, arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        model_flops_total=mf)

    row = dict(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        status="ok", compile_s=round(time.time() - t0, 1),
        hlo_flops_per_dev=terms.hlo_flops,
        hlo_bytes_per_dev=terms.hlo_bytes,
        coll_bytes_per_dev=terms.coll_bytes,
        model_flops_total=mf,
        t_compute=terms.t_compute, t_memory=terms.t_memory,
        t_collective=terms.t_collective, bottleneck=terms.bottleneck,
        useful_fraction=terms.useful_fraction, mfu=terms.mfu,
        argument_bytes=getattr(mem, "argument_size_in_bytes", None),
        output_bytes=getattr(mem, "output_size_in_bytes", None),
        temp_bytes=getattr(mem, "temp_size_in_bytes", None),
        # memory_analysis sums across the SPMD replicas -> per device:
        peak_bytes_per_dev=(getattr(mem, "argument_size_in_bytes", 0)
                            + getattr(mem, "output_size_in_bytes", 0)
                            + getattr(mem, "temp_size_in_bytes", 0)) / chips,
    )
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-scan", action="store_true")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for a in sorted(ARCHS):
            for s in ["train_4k", "prefill_32k", "decode_32k", "long_500k"]:
                cells.append((a, s, args.multi_pod))
    else:
        assert args.arch and args.shape
        cells.append((args.arch, args.shape, args.multi_pod))

    rows = []
    for arch, shape, mp in cells:
        if not cell_supported(arch, shape):
            rows.append(dict(arch=arch, shape=shape,
                             mesh="2x16x16" if mp else "16x16",
                             status=SKIP,
                             reason="pure full-attention arch at 500k "
                                    "(DESIGN.md §4)"))
            print(f"[dryrun] {arch:28s} {shape:12s} SKIP")
            continue
        try:
            row = run_cell(arch, shape, mp, args.microbatches, args.remat,
                           fsdp=not args.no_fsdp,
                           scan_layers=not args.no_scan)
            rows.append(row)
            print(f"[dryrun] {arch:28s} {shape:12s} {row['mesh']:8s} OK "
                  f"compile {row['compile_s']:6.1f}s "
                  f"peak/dev {row['peak_bytes_per_dev']/2**30:6.2f} GiB "
                  f"bottleneck {row['bottleneck']:10s} "
                  f"mfu-bound {row['mfu']:.3f}")
        except Exception as e:
            traceback.print_exc()
            rows.append(dict(arch=arch, shape=shape, status="FAIL",
                             error=str(e)[:500]))
            print(f"[dryrun] {arch:28s} {shape:12s} FAIL {e}")

    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    ok = all(r["status"] in ("ok", SKIP) for r in rows)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
