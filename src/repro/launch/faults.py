"""Fault-tolerance harness: heartbeat, straggler detection, preemption.

On a real multi-pod deployment each host runs a FaultMonitor; the
coordinator aggregates heartbeats.  The mechanisms:

  - heartbeat(step): stamps progress; a step taking longer than
    `straggler_factor` x the EMA step time flags a straggler.  Mitigation
    at framework level: the launcher excludes the slow host's pod from the
    next elastic re-mesh (drain + re-shard from the last checkpoint via
    ckpt.restore_checkpoint with the smaller mesh — see
    tests/test_distributed.py::test_elastic_restore).
  - preemption: SIGTERM flips a flag; the train loop checkpoints and
    exits cleanly at the next step boundary (checkpoint/restart).
  - simulated faults for tests: inject_straggler()/inject_preemption().
"""

from __future__ import annotations

import signal
import threading
import time
from typing import List, Optional

__all__ = ["FaultMonitor"]


class FaultMonitor:
    def __init__(self, straggler_factor: float = 3.0, ema: float = 0.9,
                 install_signal_handler: bool = False):
        self.straggler_factor = straggler_factor
        self.ema_coef = ema
        self.ema_dt: Optional[float] = None
        self.last_t: Optional[float] = None
        self.straggler_events: List[dict] = []
        self._preempted = threading.Event()
        if install_signal_handler:
            signal.signal(signal.SIGTERM, self._on_sigterm)

    # ---- heartbeat / straggler ------------------------------------------
    def heartbeat(self, step: int, now: Optional[float] = None):
        now = time.monotonic() if now is None else now
        if self.last_t is not None:
            dt = now - self.last_t
            if self.ema_dt is None:
                self.ema_dt = dt
            else:
                if dt > self.straggler_factor * self.ema_dt:
                    self.straggler_events.append(
                        dict(step=step, dt=dt, ema=self.ema_dt))
                self.ema_dt = (self.ema_coef * self.ema_dt
                               + (1 - self.ema_coef) * dt)
        self.last_t = now

    @property
    def is_straggling(self) -> bool:
        return bool(self.straggler_events)

    # ---- preemption -------------------------------------------------------
    def _on_sigterm(self, *_):
        self._preempted.set()

    def inject_preemption(self):
        self._preempted.set()

    def should_checkpoint_and_exit(self) -> bool:
        return self._preempted.is_set()
