"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE any jax
import; everything else sees the real device count.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16 x 16 = 256 chips (data, model).
    Multi-pod: 2 x 16 x 16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / smoke runs)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
