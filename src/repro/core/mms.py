"""Slim Fly MMS construction (paper §II-B).

Builds the McKay–Miller–Širáň-type graph for a prime power q = 4w + delta,
delta in {-1, 0, +1}:

  vertices  {0,1} x F_q x F_q                           (N_r = 2 q^2)
  (0,x,y) ~ (0,x,y')  iff  y - y' in X                  (Eq. 1)
  (1,m,c) ~ (1,m,c')  iff  c - c' in X'                 (Eq. 2)
  (0,x,y) ~ (1,m,c)   iff  y = m*x + c                  (Eq. 3)

Generator sets (paper gives delta=+1; the others follow Hafner [35]):
  delta=+1: X  = even powers of xi  (the quadratic residues),
            X' = odd powers of xi.
  delta=-1: X  = {±xi^(2i) : 0<=i<w},  X' = {±xi^(2i+1) : 0<=i<w}
            (both symmetric because -1 = xi^(2w-1) is an odd power).
  delta= 0: q = 2^s: X = {xi^(2i)}, X' = {xi^(2i+1)}, i in [0, q/2)
            (char 2: every set is symmetric).

All constructions are *verified* (degree = k', diameter = 2) by the test
suite; the module also asserts basic structure at build time.

Vertex index convention: (s, a, b) -> s*q^2 + a*q + b.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from .gf import GF, factor_prime_power
from .topology import Topology

__all__ = [
    "slimfly_params",
    "valid_q",
    "build_slimfly",
    "balanced_concentration",
    "enumerate_slimfly_configs",
    "SlimFly",
]


def valid_q(q: int) -> Optional[int]:
    """Return delta if q is a usable prime power (q = 4w + delta), else None."""
    if factor_prime_power(q) is None:
        return None
    for delta in (-1, 0, 1):
        if (q - delta) % 4 == 0 and (q - delta) // 4 >= 1:
            return delta
    return None


def slimfly_params(q: int) -> dict:
    delta = valid_q(q)
    if delta is None:
        raise ValueError(f"q={q} is not 4w+delta for a prime power")
    kprime = (3 * q - delta) // 2
    n_r = 2 * q * q
    p = balanced_concentration(kprime, n_r)
    return dict(q=q, delta=delta, kprime=kprime, n_routers=n_r, p=p,
                router_radix=kprime + p, n_endpoints=p * n_r)


def balanced_concentration(kprime: int, n_r: int) -> int:
    """Paper §II-B2: p ~= k' N_r / (2 N_r - k' - 2) ~= ceil(k'/2)."""
    exact = kprime * n_r / (2 * n_r - kprime - 2)
    return int(np.ceil(exact))


def _generator_sets(q: int, delta: int) -> Tuple[List[int], List[int]]:
    f = GF(q)
    xi = f.xi
    if delta == 1:
        w = (q - 1) // 4
        # X = {1, xi^2, ..., xi^(q-3)}  (even powers), X' = odd powers
        X = [f.pow(xi, 2 * i) for i in range((q - 1) // 2)]
        Xp = [f.pow(xi, 2 * i + 1) for i in range((q - 1) // 2)]
    elif delta == -1:
        w = (q + 1) // 4
        X, Xp = [], []
        for i in range(w):
            e = f.pow(xi, 2 * i)
            o = f.pow(xi, 2 * i + 1)
            X += [e, int(f.neg(e))]
            Xp += [o, int(f.neg(o))]
    else:  # delta == 0, q = 2^s
        X = [f.pow(xi, 2 * i) for i in range(q // 2)]
        Xp = [f.pow(xi, 2 * i + 1) for i in range(q // 2)]
    X, Xp = sorted(set(X)), sorted(set(Xp))
    # Symmetry (X = -X) is required for the graph to be undirected.
    for s in (X, Xp):
        for v in s:
            assert int(GF(q).neg(v)) in s, (q, delta, "generator set not symmetric")
    return X, Xp


def build_slimfly(q: int, p: Optional[int] = None) -> Topology:
    """Construct SF MMS for prime power q.  p defaults to the balanced
    concentration (full global bandwidth); pass larger p to oversubscribe
    (paper §V-E) or smaller to undersubscribe."""
    params = slimfly_params(q)
    delta, kprime, n_r = params["delta"], params["kprime"], params["n_routers"]
    if p is None:
        p = params["p"]
    f = GF(q)
    X, Xp = _generator_sets(q, delta)

    adj = np.zeros((n_r, n_r), dtype=bool)
    idx0 = lambda x, y: x * q + y            # subgraph 0 block [0, q^2)
    idx1 = lambda m, c: q * q + m * q + c    # subgraph 1 block [q^2, 2q^2)

    in_X = np.zeros(q, dtype=bool)
    in_X[X] = True
    in_Xp = np.zeros(q, dtype=bool)
    in_Xp[Xp] = True

    sub = f.sub_table  # sub[a, b] = a - b in F_q
    # Eq. (1): (0,x,y) ~ (0,x,y') iff y - y' in X
    intra0 = in_X[sub]                        # [q, q] bool over (y, y')
    # Eq. (2): (1,m,c) ~ (1,m,c') iff c - c' in X'
    intra1 = in_Xp[sub]
    for a in range(q):
        base0 = a * q
        adj[base0 : base0 + q, base0 : base0 + q] = intra0
        base1 = q * q + a * q
        adj[base1 : base1 + q, base1 : base1 + q] = intra1

    # Eq. (3): (0,x,y) ~ (1,m,c) iff y = m*x + c
    mul = f.mul_table
    add = f.add_table
    for m in range(q):
        for x in range(q):
            # y = m*x + c  for all c: vector over c
            y = add[mul[m, x], np.arange(q)]
            rows = idx0(x, y)                 # vector of q vertex ids
            cols = q * q + m * q + np.arange(q)
            adj[rows, cols] = True
            adj[cols, rows] = True

    np.fill_diagonal(adj, False)
    deg = adj.sum(axis=1)
    assert (deg == kprime).all(), (
        f"SF MMS q={q}: degree {sorted(set(deg.tolist()))} != k'={kprime}")
    return Topology(
        name=f"slimfly-q{q}",
        adj=adj,
        p=p,
        params=dict(params, X=X, Xp=Xp, family="slimfly"),
    )


# Convenience alias matching the paper's name
SlimFly = build_slimfly


def enumerate_slimfly_configs(max_endpoints: int = 200_000) -> List[dict]:
    """§VII-A: the library of practical balanced SF configurations."""
    out = []
    q = 3
    while True:
        if valid_q(q) is not None:
            par = slimfly_params(q)
            if par["n_endpoints"] > max_endpoints:
                break
            out.append(par)
        q += 1
        if q > 4096:
            break
    return out
