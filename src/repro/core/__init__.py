"""Slim Fly core: the paper's primary contribution.

- mms:      SF MMS diameter-2 construction over GF(q) (paper §II-B)
- moore:    Moore bound + optimality comparisons (§II-A, Fig 5)
- topology: graph abstraction + exact oracles
- topologies: comparison networks (Table II)
- routing:  MIN/VAL/UGAL path generation, VC assignment, deadlock proofs (§IV)
- resiliency: link-failure analyses (§III-D)
- cost:     cost/power/layout models (§VI)
"""

from .gf import GF, factor_prime_power, is_prime
from .mms import (
    SlimFly,
    balanced_concentration,
    build_slimfly,
    enumerate_slimfly_configs,
    slimfly_params,
    valid_q,
)
from .moore import moore_bound
from .topology import (Topology, apply_link_failures, bfs_all_pairs,
                       masked_adjacency, normalize_failed_edges)

__all__ = [
    "GF",
    "factor_prime_power",
    "is_prime",
    "SlimFly",
    "balanced_concentration",
    "build_slimfly",
    "enumerate_slimfly_configs",
    "slimfly_params",
    "valid_q",
    "moore_bound",
    "Topology",
    "bfs_all_pairs",
    "apply_link_failures",
    "masked_adjacency",
    "normalize_failed_edges",
]
