"""Cost and power models (paper §VI-B, §VI-C, Figs 11-13, Table IV).

Cable cost is a linear function of length in $/Gb/s (regression constants
from the paper), multiplied by the link bandwidth.  Router cost is linear
in radix: f(k) = 350.4 k - 892.3 [$].  Power: 4 SerDes lanes per port at
0.7 W each => 2.8 W per port.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from .layout import Layout, make_layout
from .topology import Topology

__all__ = ["CableModel", "CABLE_MODELS", "router_cost", "network_cost",
           "network_power"]


@dataclasses.dataclass(frozen=True)
class CableModel:
    name: str
    electric: tuple      # ($/Gb/s per m slope, intercept)
    fiber: tuple
    gbps: float


CABLE_MODELS: Dict[str, CableModel] = {
    # Mellanox InfiniBand FDR10 40Gb/s QSFP (paper's headline model, Fig 13a)
    "fdr10": CableModel("Mellanox IB FDR10 40G QSFP",
                        electric=(0.4079, 0.5771),
                        fiber=(0.0919, 2.7452), gbps=40.0),
    # Elpeus Ethernet 10Gb/s SFP+ (Fig 12) — same shape, rescaled intercepts
    "elpeus10g": CableModel("Elpeus Ethernet 10G SFP+",
                            electric=(0.9, 1.5),
                            fiber=(0.16, 5.0), gbps=10.0),
    # Mellanox IB QDR56 56Gb/s QSFP (Fig 13)
    "qdr56": CableModel("Mellanox IB QDR56 56G QSFP",
                        electric=(0.35, 0.5),
                        fiber=(0.08, 2.2), gbps=56.0),
}


def router_cost(k: int) -> float:
    """Paper §VI-B2: linear fit over Mellanox IB FDR10 routers."""
    return 350.4 * k - 892.3


def network_cost(topo: Topology, layout: Optional[Layout] = None,
                 cable: str = "fdr10",
                 router_radix: Optional[int] = None) -> dict:
    """Total and per-endpoint network cost.

    router_radix overrides the billed router radix (the paper's Table IV
    bills SF's routers at k = 43).  Endpoint up-links are intra-rack
    electric cables (1 m), one per endpoint.
    """
    layout = layout or make_layout(topo)
    cm = CABLE_MODELS[cable]
    is_fiber, length = layout.cable_lengths()

    el_slope, el_int = cm.electric
    fb_slope, fb_int = cm.fiber
    cost_el = ((el_slope * length[~is_fiber] + el_int) * cm.gbps).sum()
    cost_fb = ((fb_slope * length[is_fiber] + fb_int) * cm.gbps).sum()
    # endpoint up-links: N electric cables of ~1 m
    n_ep = topo.n_endpoints
    cost_ep = n_ep * (el_slope * 1.0 + el_int) * cm.gbps

    k = router_radix if router_radix is not None else topo.router_radix
    cost_routers = topo.n_routers * router_cost(k)

    total = cost_el + cost_fb + cost_ep + cost_routers
    return dict(
        n_electric=int((~is_fiber).sum()), n_fiber=int(is_fiber.sum()),
        cost_cables_electric=float(cost_el), cost_cables_fiber=float(cost_fb),
        cost_endpoint_links=float(cost_ep), cost_routers=float(cost_routers),
        total=float(total), per_endpoint=float(total / n_ep),
        avg_fiber_len=float(length[is_fiber].mean()) if is_fiber.any() else 0.0,
    )


def network_power(topo: Topology, router_radix: Optional[int] = None,
                  watts_per_serdes: float = 0.7, lanes_per_port: int = 4
                  ) -> dict:
    """Paper §VI-C: power = ports * lanes * W_serdes, summed over routers."""
    k = router_radix if router_radix is not None else topo.router_radix
    per_port = lanes_per_port * watts_per_serdes
    total = topo.n_routers * k * per_port
    return dict(total_w=float(total),
                per_endpoint_w=float(total / topo.n_endpoints))
