"""Base network-topology abstraction used throughout the framework.

A Topology is an undirected simple graph of routers plus a concentration p
(endpoints per router).  Heavy analyses (APSP, resiliency) run on the JAX /
Pallas path (`repro.core.routing`, `repro.kernels`); this module keeps the
graph itself in numpy for cheap construction and exact checks.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

__all__ = ["Topology", "edges_from_adj", "bfs_all_pairs",
           "normalize_failed_edges", "masked_adjacency",
           "apply_link_failures"]


@dataclasses.dataclass
class Topology:
    name: str
    adj: np.ndarray          # bool [N_r, N_r], symmetric, no self loops
    p: int                   # concentration (endpoints per endpoint-router)
    params: Dict = dataclasses.field(default_factory=dict)
    # Routers that carry endpoints (None = all).  Fat trees only attach
    # endpoints at edge routers.
    endpoint_mask: Optional[np.ndarray] = None

    def __post_init__(self):
        a = self.adj
        assert a.dtype == bool and a.shape[0] == a.shape[1]
        assert not a.diagonal().any(), "self loops"
        assert (a == a.T).all(), "adjacency must be symmetric"
        if self.endpoint_mask is not None:
            assert self.endpoint_mask.shape == (a.shape[0],)

    # -- basic quantities ---------------------------------------------------
    @property
    def n_routers(self) -> int:
        return self.adj.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        return self.adj.sum(axis=1)

    @property
    def network_radix(self) -> int:           # k'
        return int(self.degrees.max())

    @property
    def router_radix(self) -> int:
        """k = max over routers of (network degree + endpoint ports).
        Endpoint ports only exist on endpoint routers (fat tree: edge)."""
        deg = self.degrees
        if self.endpoint_mask is None:
            return int(deg.max()) + self.p
        k_ep = int(deg[self.endpoint_mask].max()) + self.p
        k_net = int(deg.max())
        return max(k_ep, k_net)

    @property
    def n_endpoint_routers(self) -> int:
        if self.endpoint_mask is None:
            return self.n_routers
        return int(self.endpoint_mask.sum())

    @property
    def n_endpoints(self) -> int:             # N
        return self.p * self.n_endpoint_routers

    @property
    def n_edges(self) -> int:
        return int(self.adj.sum()) // 2

    # -- views ----------------------------------------------------------------
    def neighbor_lists(self, pad_to: Optional[int] = None) -> np.ndarray:
        """[N_r, max_deg] neighbor ids, padded with -1 (for JAX consumption)."""
        deg = self.degrees
        width = pad_to or int(deg.max())
        out = np.full((self.n_routers, width), -1, dtype=np.int32)
        for r in range(self.n_routers):
            nbrs = np.nonzero(self.adj[r])[0]
            out[r, : len(nbrs)] = nbrs
        return out

    def edge_list(self) -> np.ndarray:
        return edges_from_adj(self.adj)

    # -- exact (numpy BFS) analyses — used as test oracles ---------------------
    def distance_matrix(self) -> np.ndarray:
        return bfs_all_pairs(self.adj)

    def diameter(self) -> int:
        d = self.distance_matrix()
        return int(d.max()) if np.isfinite(d).all() else -1

    def average_router_distance(self) -> float:
        d = self.distance_matrix()
        n = self.n_routers
        return float(d.sum() / (n * (n - 1)))

    def average_endpoint_hops(self) -> float:
        """Average #router-router hops between two distinct endpoints
        (endpoints on the same router: 0 hops).  This is the Fig-1 metric."""
        d = self.distance_matrix()
        if self.endpoint_mask is not None:
            d = d[np.ix_(self.endpoint_mask, self.endpoint_mask)]
        n, p = d.shape[0], self.p
        total_pairs = (n * p) * (n * p - 1)
        inter = d.sum() * p * p           # pairs on distinct routers
        return float(inter / total_pairs)

    def is_connected(self) -> bool:
        return np.isfinite(self.distance_matrix()).all()


def edges_from_adj(adj: np.ndarray) -> np.ndarray:
    iu = np.triu_indices(adj.shape[0], k=1)
    mask = adj[iu]
    return np.stack([iu[0][mask], iu[1][mask]], axis=1).astype(np.int32)


def normalize_failed_edges(failed_edges, topo: Optional["Topology"] = None
                           ) -> np.ndarray:
    """Canonical failure mask: int32 [K, 2] of undirected router pairs.

    Accepts an [K, 2] array of router-id pairs (either endpoint order) or,
    when `topo` is given, a bool mask over `topo.edge_list()` rows.  The
    empty mask is a valid (healthy) input.
    """
    fe = np.asarray(failed_edges)
    if fe.dtype == bool:
        assert topo is not None, "bool edge mask needs the topology"
        edges = topo.edge_list()
        assert fe.shape == (len(edges),), (fe.shape, len(edges))
        fe = edges[fe]
    fe = fe.reshape(-1, 2).astype(np.int32)
    return fe


def masked_adjacency(adj: np.ndarray, failed_edges: np.ndarray) -> np.ndarray:
    """Adjacency with the failed undirected edges removed (both directions)."""
    out = adj.copy()
    fe = normalize_failed_edges(failed_edges)
    out[fe[:, 0], fe[:, 1]] = False
    out[fe[:, 1], fe[:, 0]] = False
    return out


def apply_link_failures(topo: Topology, failed_edges) -> Topology:
    """Degraded copy of `topo` with the masked links removed.  Keeps p,
    params and the endpoint mask; only the router graph changes."""
    fe = normalize_failed_edges(failed_edges, topo)
    if len(fe) == 0:
        return topo
    return Topology(
        name=f"{topo.name}-f{len(fe)}",
        adj=masked_adjacency(topo.adj, fe),
        p=topo.p,
        params=dict(topo.params, failed_edges=len(fe)),
        endpoint_mask=(None if topo.endpoint_mask is None
                       else topo.endpoint_mask.copy()),
    )


def bfs_all_pairs(adj: np.ndarray) -> np.ndarray:
    """Exact APSP over an unweighted graph via repeated frontier expansion.
    Uses float32 matmul (BLAS) — bool matmul in numpy has no fast path.
    Unreachable pairs get +inf."""
    n = adj.shape[0]
    adj_f = adj.astype(np.float32)
    dist = np.full((n, n), np.inf)
    np.fill_diagonal(dist, 0.0)
    reach = np.eye(n, dtype=bool)
    frontier = np.eye(n, dtype=np.float32)
    d = 0
    while frontier.any():
        d += 1
        nxt = ((frontier @ adj_f) > 0) & ~reach
        dist[nxt] = d
        reach |= nxt
        frontier = nxt.astype(np.float32)
        if d > n:
            break
    return dist
