"""k-ary n-cube torus topologies (T3D, T5D) [3], [21]; p = 1."""

from __future__ import annotations

import itertools

import numpy as np

from ..topology import Topology

__all__ = ["build_torus"]


def build_torus(radix_per_dim, n_dims: int = None, p: int = 1) -> Topology:
    """radix_per_dim: int (uniform) or sequence of per-dim sizes."""
    if isinstance(radix_per_dim, int):
        assert n_dims is not None
        dims = [radix_per_dim] * n_dims
    else:
        dims = list(radix_per_dim)
    n_dims = len(dims)
    n_r = int(np.prod(dims))
    coords = np.array(list(itertools.product(*[range(d) for d in dims])))
    strides = np.ones(n_dims, dtype=np.int64)
    for d in range(n_dims - 2, -1, -1):
        strides[d] = strides[d + 1] * dims[d + 1]
    idx_of = lambda cd: int((cd * strides).sum())

    adj = np.zeros((n_r, n_r), dtype=bool)
    for i in range(n_r):
        cd = coords[i]
        for d in range(n_dims):
            if dims[d] < 2:
                continue
            for step in (+1, -1):
                nb = cd.copy()
                nb[d] = (nb[d] + step) % dims[d]
                j = idx_of(nb)
                if j != i:
                    adj[i, j] = True
                    adj[j, i] = True
    np.fill_diagonal(adj, False)
    return Topology(
        name=f"torus-{'x'.join(map(str, dims))}",
        adj=adj,
        p=p,
        params=dict(dims=dims, family=f"torus{n_dims}d"),
    )
