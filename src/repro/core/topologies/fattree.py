"""3-level fat tree (p-ary 3-tree, folded Clos) [44].

The paper's FT-3 (§V: k = 44, p = 22, N_r = 1452, N = 10648) is a p-ary
3-tree with p = k/2:
  - 3 levels x p^2 routers  (N_r = 3 p^2),
  - edge router: p endpoints + p up-links (one per agg in its pod),
  - p pods of (p edge + p agg) routers,
  - agg router j of a pod: p down + p up-links to core group j,
  - p^2 core routers in p groups; core group j connects agg-index-j of
    every pod.
  - N = p^3 endpoints; router-level diameter 4.
Endpoints live only on edge routers (endpoint_mask).
"""

from __future__ import annotations

import numpy as np

from ..topology import Topology

__all__ = ["build_fattree3"]


def build_fattree3(k: int = None, p: int = None) -> Topology:
    """Build from router radix k (p = k//2) or directly from p."""
    if p is None:
        assert k is not None and k % 2 == 0, "need even k or explicit p"
        p = k // 2
    k = 2 * p
    n_level = p * p
    n_r = 3 * n_level

    edge = lambda pod, i: pod * p + i                    # level 0
    agg = lambda pod, j: n_level + pod * p + j           # level 1
    core = lambda j, c: 2 * n_level + j * p + c          # level 2

    adj = np.zeros((n_r, n_r), dtype=bool)
    for pod in range(p):
        for i in range(p):
            for j in range(p):
                adj[edge(pod, i), agg(pod, j)] = True
        for j in range(p):
            for c in range(p):
                adj[agg(pod, j), core(j, c)] = True
    adj |= adj.T

    endpoint_mask = np.zeros(n_r, dtype=bool)
    endpoint_mask[:n_level] = True
    return Topology(
        name=f"fattree3-k{k}",
        adj=adj,
        p=p,
        params=dict(k=k, n_core=n_level, family="fattree3"),
        endpoint_mask=endpoint_mask,
    )
