"""Hypercube topology [59]; p = 1."""

from __future__ import annotations

import numpy as np

from ..topology import Topology

__all__ = ["build_hypercube"]


def build_hypercube(n_dims: int, p: int = 1) -> Topology:
    n_r = 1 << n_dims
    ids = np.arange(n_r)
    adj = np.zeros((n_r, n_r), dtype=bool)
    for d in range(n_dims):
        nb = ids ^ (1 << d)
        adj[ids, nb] = True
    np.fill_diagonal(adj, False)
    return Topology(
        name=f"hypercube-{n_dims}",
        adj=adj,
        p=p,
        params=dict(n_dims=n_dims, family="hypercube"),
    )
