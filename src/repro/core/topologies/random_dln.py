"""Random shortcut topologies DLN-2-y (Koibuchi et al. [42]).

Base ring (degree 2) + y random shortcut edges per vertex.  We add y random
perfect matchings (seeded, deterministic) so the graph stays regular with
degree 2 + y.  Paper: p = floor(sqrt(k))."""

from __future__ import annotations

import numpy as np

from ..topology import Topology

__all__ = ["build_dln"]


def build_dln(n_r: int, y: int, p: int = None, seed: int = 0) -> Topology:
    assert n_r % 2 == 0, "random matchings need even N_r"
    rng = np.random.default_rng(seed)
    adj = np.zeros((n_r, n_r), dtype=bool)
    ids = np.arange(n_r)
    adj[ids, (ids + 1) % n_r] = True
    adj[(ids + 1) % n_r, ids] = True

    added = 0
    attempts = 0
    while added < y and attempts < 100 * y:
        attempts += 1
        perm = rng.permutation(n_r)
        pairs = perm.reshape(-1, 2)
        # reject matchings that duplicate an existing edge or self-pair
        if adj[pairs[:, 0], pairs[:, 1]].any():
            continue
        adj[pairs[:, 0], pairs[:, 1]] = True
        adj[pairs[:, 1], pairs[:, 0]] = True
        added += 1
    if added < y:
        raise RuntimeError("could not place all random matchings")

    np.fill_diagonal(adj, False)
    k = 2 + y + (p or 0)
    if p is None:
        p = int(np.floor(np.sqrt(2 + y + np.sqrt(2 + y)))) or 1
    return Topology(
        name=f"dln-2-{y}-n{n_r}",
        adj=adj,
        p=p,
        params=dict(y=y, seed=seed, family="dln"),
    )
