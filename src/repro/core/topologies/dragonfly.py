"""Dragonfly topology (Kim et al. [41]) — the paper's main competitor.

Balanced configuration: a = 2p = 2h, g = a*h + 1 groups.
  a: routers per group (intra-group clique)
  h: global (inter-group) links per router
  p: endpoints per router
Router radix k = (a-1) + h + p = 4h - 1  =>  p = h = (k+1)/4.

Global-link arrangement (canonical): the g groups form a clique; the link
between groups u < v with offset d = v - u is carried, on u's side, by
global port (d-1) i.e. router (d-1) // h, and on v's side by global port
(g - 1 - d) i.e. router (g - 1 - d) // h.  Every group has exactly a*h =
g - 1 global ports, one per other group.
"""

from __future__ import annotations

import numpy as np

from ..topology import Topology

__all__ = ["build_dragonfly", "dragonfly_for_radix"]


def build_dragonfly(h: int, a: int = None, p: int = None) -> Topology:
    a = 2 * h if a is None else a
    p = h if p is None else p
    g = a * h + 1
    n_r = a * g
    adj = np.zeros((n_r, n_r), dtype=bool)
    rid = lambda grp, r: grp * a + r

    # intra-group cliques
    for grp in range(g):
        base = grp * a
        adj[base : base + a, base : base + a] = True

    # global links
    for u in range(g):
        for d in range(1, g):
            v = (u + d) % g
            if u < v:
                ru = rid(u, (d - 1) // h)
                rv = rid(v, (g - 1 - d) // h)
                adj[ru, rv] = True
                adj[rv, ru] = True

    np.fill_diagonal(adj, False)
    deg = adj.sum(axis=1)
    assert (deg == a - 1 + h).all(), f"DF degree mismatch: {set(deg.tolist())}"
    return Topology(
        name=f"dragonfly-h{h}",
        adj=adj,
        p=p,
        params=dict(a=a, h=h, g=g, family="dragonfly"),
    )


def dragonfly_for_radix(k: int) -> Topology:
    """Balanced DF for router radix k (paper: p = floor((k+1)/4))."""
    h = (k + 1) // 4
    return build_dragonfly(h=h)
