"""Brown / Erdős–Rényi polarity graph P_u over PG(2, u) — the diameter-2
building block of the Bermond–Delorme–Fahri diameter-3 construction
(paper §II-C1b).

Vertices are the u^2 + u + 1 projective points of PG(2, u); two points
M_i, M_j are adjacent iff <M_i, M_j> = 0 (orthogonal polarity), i.e.
M_j lies on the polar line D_i of M_i.  Degree u + 1 (u for the u + 1
absolute points whose self-loop is removed); diameter 2.
"""

from __future__ import annotations

import numpy as np

from ..gf import GF
from ..topology import Topology

__all__ = ["build_polarity_graph", "projective_points"]


def projective_points(u: int) -> np.ndarray:
    """Canonical representatives of PG(2, u): (1,b,c), (0,1,c), (0,0,1)."""
    pts = [(1, b, c) for b in range(u) for c in range(u)]
    pts += [(0, 1, c) for c in range(u)]
    pts += [(0, 0, 1)]
    return np.array(pts, dtype=np.int64)


def build_polarity_graph(u: int, p: int = 1) -> Topology:
    f = GF(u)
    pts = projective_points(u)
    n = len(pts)
    add, mul = f.add_table, f.mul_table
    # dot(M_i, M_j) over GF(u)
    dot = np.zeros((n, n), dtype=np.int64)
    for axis in range(3):
        dot = add[dot, mul[np.ix_(pts[:, axis], pts[:, axis])]]
    adj = dot == 0
    np.fill_diagonal(adj, False)
    return Topology(
        name=f"polarity-u{u}",
        adj=adj,
        p=p,
        params=dict(u=u, family="polarity"),
    )
