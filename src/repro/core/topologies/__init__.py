"""Comparison topologies from paper Table II (+ diameter-3 constructions)."""

from .dragonfly import build_dragonfly, dragonfly_for_radix
from .fattree import build_fattree3
from .flat_butterfly import build_flattened_butterfly
from .torus import build_torus
from .hypercube import build_hypercube
from .random_dln import build_dln
from .longhop import build_longhop_hc
from .polarity import build_polarity_graph
from .bdf import build_bdf, slimfly_dragonfly, star_product

__all__ = [
    "build_dragonfly",
    "dragonfly_for_radix",
    "build_fattree3",
    "build_flattened_butterfly",
    "build_torus",
    "build_hypercube",
    "build_dln",
    "build_longhop_hc",
    "build_polarity_graph",
    "build_bdf",
    "slimfly_dragonfly",
    "star_product",
]
