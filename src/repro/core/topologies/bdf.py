"""Bermond–Delorme–Fahri diameter-3 construction (paper §II-C1).

The * product (Bermond, Delorme, Farhi 1982): G' = G1 * G2 with
V' = V1 x V2 and (a1,a2) ~ (b1,b2) iff
  a1 == b1 and {a2, b2} in E2,   or
  (a1, b1) in U (an orientation of E1) and b2 = f_(a1,b1)(a2).

With G1 = P_u (the diameter-2 polarity graph) and G2 = K_n carrying the
identity involution (K_n satisfies property P*: V = {v} ∪ Γ(v)), the
product has diameter <= 3 and degree deg(P_u) + n - 1 (verified by
tests).  The paper's optimal BDF graphs use richer P* graphs from [6];
K_n gives the same diameter bound at a smaller N_r — the asymptotic
N_r formula of §II-C is covered analytically in core/moore.py.
"""

from __future__ import annotations

import numpy as np

from ..topology import Topology
from .polarity import build_polarity_graph

__all__ = ["star_product", "build_bdf"]


def star_product(g1: Topology, g2: Topology, name: str = "star") -> Topology:
    """G1 * G2 with identity arc maps f_(x,y) = id (valid whenever G2's
    involution is the identity, e.g. complete graphs)."""
    n1, n2 = g1.n_routers, g2.n_routers
    n = n1 * n2
    adj = np.zeros((n, n), dtype=bool)
    idx = lambda a1, a2: a1 * n2 + a2

    # intra: same G1 vertex, G2 edges
    for a1 in range(n1):
        base = a1 * n2
        adj[base:base + n2, base:base + n2] = g2.adj

    # cross: G1 arcs with identity mapping -> (a1, t) ~ (b1, t)
    e1 = g1.edge_list()
    for a1, b1 in e1:
        for t in range(n2):
            adj[idx(a1, t), idx(b1, t)] = True
            adj[idx(b1, t), idx(a1, t)] = True

    np.fill_diagonal(adj, False)
    return Topology(name=name, adj=adj, p=1,
                    params=dict(family="bdf", n1=n1, n2=n2))


def build_bdf(u: int, n: int | None = None, p: int | None = None
              ) -> Topology:
    """P_u * K_n.  Default n = (u+3)/2 (so k' ~ 3(u+1)/2, §II-C1c).
    p defaults to ceil(k'/2) (balanced, as for SF)."""
    pu = build_polarity_graph(u)
    if n is None:
        n = max(2, (u + 3) // 2)
    kn = Topology(name=f"K{n}", adj=~np.eye(n, dtype=bool), p=1,
                  params=dict(family="complete"))
    topo = star_product(pu, kn, name=f"bdf-u{u}-n{n}")
    kprime = topo.network_radix
    topo.p = p if p is not None else int(np.ceil(kprime / 2))
    topo.params.update(u=u, n=n)
    return topo


def slimfly_dragonfly(q: int, n_groups: int, links_per_pair: int = 1
                      ) -> Topology:
    """Paper §VII-B: use Slim Fly graphs as the GROUPS of a Dragonfly —
    higher-radix "logical routers" at lower cost than DF's cliques.
    n_groups SF(q) groups, fully connected at the group level with
    `links_per_pair` cables per pair, spread round-robin over routers."""
    from ..mms import build_slimfly
    sf = build_slimfly(q)
    ng = sf.n_routers
    n = ng * n_groups
    adj = np.zeros((n, n), dtype=bool)
    for g in range(n_groups):
        base = g * ng
        adj[base:base + ng, base:base + ng] = sf.adj
    # group-level clique: pair (g1, g2) uses routers chosen round-robin
    pair_idx = 0
    for g1 in range(n_groups):
        for g2 in range(g1 + 1, n_groups):
            for c in range(links_per_pair):
                r1 = g1 * ng + (pair_idx + c) % ng
                r2 = g2 * ng + (pair_idx + c) % ng
                adj[r1, r2] = True
                adj[r2, r1] = True
            pair_idx += links_per_pair
    np.fill_diagonal(adj, False)
    return Topology(name=f"sf-df-q{q}-g{n_groups}", adj=adj, p=sf.p,
                    params=dict(family="sf_dragonfly", q=q,
                                n_groups=n_groups))
