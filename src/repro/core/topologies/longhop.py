"""Long Hop hypercube-augmented topology (Tomic [56], Section E-S-3),
simplified.

Long Hops are Cayley graphs over Z_2^n whose generator set extends the
hypercube's unit vectors with codewords of a good linear code, raising
bisection bandwidth (paper cites 3N/2).  The exact code tables from [56]
are not public; we follow the *structure*: unit vectors + L extra
odd-weight generators drawn deterministically (seeded) with pairwise
distinct values — matching the radix the paper reports (e.g. k = 19 =
13 + 6 for N = 8192, i.e. L = floor(n/2)).  DESIGN.md records this as a
deviation (the paper itself treats LH-HC analytically for most metrics).
"""

from __future__ import annotations

import numpy as np

from ..topology import Topology

__all__ = ["build_longhop_hc"]


def build_longhop_hc(n_dims: int, extra: int = None, p: int = 1,
                     seed: int = 7) -> Topology:
    n_r = 1 << n_dims
    L = extra if extra is not None else n_dims // 2
    rng = np.random.default_rng(seed)
    gens = [1 << d for d in range(n_dims)]
    seen = set(gens)
    while len(gens) < n_dims + L:
        g = int(rng.integers(1, n_r))
        if g in seen or bin(g).count("1") % 2 == 0 or bin(g).count("1") < 3:
            continue
        seen.add(g)
        gens.append(g)

    ids = np.arange(n_r)
    adj = np.zeros((n_r, n_r), dtype=bool)
    for g in gens:
        adj[ids, ids ^ g] = True
    np.fill_diagonal(adj, False)
    return Topology(
        name=f"longhop-{n_dims}+{L}",
        adj=adj,
        p=p,
        params=dict(n_dims=n_dims, extra=L, generators=gens, family="longhop"),
    )
