"""Flattened Butterfly [40]: Hamming graph H(n, c) — n dimensions of size c,
clique along each dimension.

FBF-3 (diameter 3): n = 3, degree 3(c-1), k = 4c - 3  =>  c = p = (k+3)/4,
matching the paper's p = floor((k+3)/4) and the §VI-B3d layout (p routers
per group, p^2 groups, p links between co-row/col groups).
FBF-2 (diameter 2): n = 2 — used in the Fig 5a Moore-bound comparison.
"""

from __future__ import annotations

import itertools

import numpy as np

from ..topology import Topology

__all__ = ["build_flattened_butterfly"]


def build_flattened_butterfly(c: int, n: int = 3) -> Topology:
    n_r = c**n
    adj = np.zeros((n_r, n_r), dtype=bool)
    coords = np.array(list(itertools.product(range(c), repeat=n)))  # [n_r, n]
    # routers differing in exactly one coordinate are connected
    for dim in range(n):
        other = [d for d in range(n) if d != dim]
        key = np.zeros(n_r, dtype=np.int64)
        for d in other:
            key = key * c + coords[:, d]
        order = np.argsort(key, kind="stable")
        for start in range(0, n_r, c):
            grp = order[start : start + c]
            adj[np.ix_(grp, grp)] = True
    np.fill_diagonal(adj, False)
    deg = adj.sum(axis=1)
    assert (deg == n * (c - 1)).all()
    return Topology(
        name=f"fbf{n}-c{c}",
        adj=adj,
        p=c,
        params=dict(c=c, n=n, family=f"fbf{n}"),
    )
