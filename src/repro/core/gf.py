"""Finite field GF(q) arithmetic for q = p^m (table based, small q).

The MMS / Slim Fly construction (paper §II-B) needs a commutative field
F_q with a primitive element xi.  For prime q this is Z_q; for prime powers
(q = 25, 27, 49, ...) we build GF(p^m) as polynomials over GF(p) modulo an
irreducible polynomial found by exhaustive search (q is small: the paper's
practical library tops out around q ~ 100).

Elements are encoded as integers in [0, q): the integer's base-p digits are
the polynomial coefficients (digit i = coefficient of x^i).
"""

from __future__ import annotations

import numpy as np

__all__ = ["GF", "is_prime", "factor_prime_power"]


def is_prime(n: int) -> bool:
    if n < 2:
        return False
    i = 2
    while i * i <= n:
        if n % i == 0:
            return False
        i += 1
    return True


def factor_prime_power(q: int):
    """Return (p, m) with q == p**m, or None if q is not a prime power."""
    if q < 2:
        return None
    for p in range(2, q + 1):
        if p * p > q:
            break
        if q % p == 0:
            m, r = 0, q
            while r % p == 0:
                r //= p
                m += 1
            return (p, m) if r == 1 else None
    return (q, 1)  # q itself prime


def _poly_mul_mod(a: int, b: int, p: int, m: int, red: tuple) -> int:
    """Multiply two GF(p)[x] polynomials (base-p encoded) mod the monic
    irreducible `red` (tuple of m coefficients of x^0..x^{m-1}; x^m is
    implicitly reduced to -red)."""
    # polynomial coefficients
    ca = [(a // p**i) % p for i in range(m)]
    cb = [(b // p**i) % p for i in range(m)]
    prod = [0] * (2 * m - 1)
    for i, ai in enumerate(ca):
        if ai:
            for j, bj in enumerate(cb):
                prod[i + j] = (prod[i + j] + ai * bj) % p
    # reduce: x^m = -red
    for d in range(2 * m - 2, m - 1, -1):
        c = prod[d]
        if c:
            prod[d] = 0
            for i in range(m):
                prod[d - m + i] = (prod[d - m + i] - c * red[i]) % p
    return sum(prod[i] * p**i for i in range(m))


def _find_irreducible(p: int, m: int) -> tuple:
    """Monic irreducible polynomial of degree m over GF(p), returned as the
    m low-order coefficients (x^m coefficient implicit 1).  Exhaustive search
    with an irreducibility test by checking it has no roots in any proper
    subfield extension — implemented via the standard 'x^(p^m) == x and
    gcd conditions' shortcut replaced, for tiny m, by brute-force trial
    division over all monic factors of degree <= m//2."""
    def poly_from_int(n, deg):
        return [(n // p**i) % p for i in range(deg + 1)]

    def poly_mod(num, den, pmod):
        num = num[:]
        dd = len(den) - 1
        while len(num) - 1 >= dd and any(num):
            if num[-1] == 0:
                num.pop()
                continue
            shift = len(num) - 1 - dd
            factor = (num[-1] * pow(den[-1], -1, pmod)) % pmod
            for i, d in enumerate(den):
                num[shift + i] = (num[shift + i] - factor * d) % pmod
            while num and num[-1] == 0:
                num.pop()
        return num

    for n in range(p**m, 2 * p**m):
        cand = poly_from_int(n, m)  # monic degree-m (n in [p^m, 2p^m) => top digit 1)
        if cand[-1] != 1:
            continue
        irreducible = True
        for d in range(1, m // 2 + 1):
            for fn in range(p**d, 2 * p**d):
                f = poly_from_int(fn, d)
                if f[-1] != 1:
                    continue
                if not poly_mod(cand, f, p):
                    irreducible = False
                    break
            if not irreducible:
                break
        if irreducible:
            return tuple(cand[:m])
    raise RuntimeError(f"no irreducible polynomial found for GF({p}^{m})")


class GF:
    """Finite field GF(q).  Cached per q; exposes dense numpy op tables."""

    _cache: dict = {}

    def __new__(cls, q: int):
        if q in cls._cache:
            return cls._cache[q]
        inst = super().__new__(cls)
        cls._cache[q] = inst
        return inst

    def __init__(self, q: int):
        if hasattr(self, "q"):  # cached instance, already initialised
            return
        pp = factor_prime_power(q)
        if pp is None:
            raise ValueError(f"q={q} is not a prime power")
        self.q = q
        self.p, self.m = pp
        if self.m == 1:
            idx = np.arange(q, dtype=np.int64)
            self.add_table = (idx[:, None] + idx[None, :]) % q
            self.sub_table = (idx[:, None] - idx[None, :]) % q
            self.mul_table = (idx[:, None] * idx[None, :]) % q
            self.neg_table = (-idx) % q
        else:
            p, m = self.p, self.m
            red = _find_irreducible(p, m)
            self._red = red
            idx = np.arange(q, dtype=np.int64)
            # addition: digitwise mod-p add of base-p representations
            digits = np.stack([(idx // p**i) % p for i in range(m)], axis=1)
            weights = np.array([p**i for i in range(m)], dtype=np.int64)
            dsum = (digits[:, None, :] + digits[None, :, :]) % p
            self.add_table = (dsum * weights).sum(axis=2)
            dneg = (-digits) % p
            self.neg_table = (dneg * weights).sum(axis=1)
            self.sub_table = self.add_table[:, self.neg_table]
            mul = np.zeros((q, q), dtype=np.int64)
            for a in range(q):
                for b in range(a, q):
                    v = _poly_mul_mod(a, b, p, m, red)
                    mul[a, b] = v
                    mul[b, a] = v
            self.mul_table = mul
        self.xi = self._find_primitive()

    # -- scalar ops -------------------------------------------------------
    def add(self, a, b):
        return self.add_table[a, b]

    def sub(self, a, b):
        return self.sub_table[a, b]

    def mul(self, a, b):
        return self.mul_table[a, b]

    def neg(self, a):
        return self.neg_table[a]

    def pow(self, a: int, e: int) -> int:
        r = 1
        for _ in range(e):
            r = int(self.mul_table[r, a])
        return r

    def _find_primitive(self) -> int:
        """Smallest primitive element xi (multiplicative order q-1).
        Exhaustive search — the strategy the paper itself uses (§II-B1a)."""
        if self.q == 2:
            return 1
        target = self.q - 1
        for cand in range(2, self.q):
            seen = set()
            v = 1
            for _ in range(target):
                v = int(self.mul_table[v, cand])
                if v in seen:
                    break
                seen.add(v)
            if len(seen) == target:
                return cand
        raise RuntimeError(f"no primitive element in GF({self.q})")

    def powers(self, base: int, n: int) -> list:
        """[base^0, base^1, ..., base^{n-1}]"""
        out, v = [], 1
        for _ in range(n):
            out.append(v)
            v = int(self.mul_table[v, base])
        return out
