"""Resiliency analyses under random link failures (paper §III-D).

Two families of metrics share one sampling/sweep engine
(:func:`failure_edge_sample` + :func:`_fraction_sweep`):

GRAPH metrics (the seed reproduction of Table III) — each reported as
the maximum fraction of links that can be removed while the majority of
samples still satisfies:
  - 'disconnect':  stays connected                       (§III-D1, Table III)
  - 'diameter':    diameter <= original + 2              (§III-D2)
  - 'avgpath':     average path length <= original + 1   (§III-D3)

ROUTED metrics (the operational view, cf. Blach et al. 2023): what MIN
routing re-converged on the masked adjacency actually delivers —
reroute success rate, path stretch and channel-load inflation
(:func:`repro.core.routing.routed_resiliency_metrics`).
:func:`routed_resilience_sweep` batches the Pallas min-plus APSP kernel
over all failure samples of a fraction in one call.

Engines: 'scipy' (C BFS — large networks), 'kernel' (batched Pallas
min-plus APSP — exercises the TPU path, used for small networks/tests).

Sweep contract: `resilience_sweep` stops early at the first fraction
with survival rate 0.0 (that fraction IS included in the result);
larger fractions are absent from the returned dict and MUST be treated
as failed by consumers.  `max_tolerated_fraction` honours this by
scanning fractions in ascending order and stopping at the first one
below threshold, so a missing tail (or a non-monotone rebound after a
sub-threshold fraction) can never inflate the Table III number.
"""

from __future__ import annotations

from typing import Callable, Dict, Literal, Optional

import numpy as np
import scipy.sparse as sp
import scipy.sparse.csgraph as csgraph

from ..kernels import apsp
from .routing import build_routing, routed_resiliency_metrics
from .topology import Topology, masked_adjacency

__all__ = ["failure_edge_sample", "failure_sample", "metric_after_failures",
           "resilience_sweep", "max_tolerated_fraction",
           "routed_resilience_sweep"]

Metric = Literal["disconnect", "diameter", "avgpath"]


def failure_edge_sample(topo: Topology, fraction: float,
                        rng: np.random.Generator) -> np.ndarray:
    """floor(fraction * |E|) random undirected edges, as an [K, 2] mask
    (the DESIGN.md §8 convention, consumable by every fault-aware layer)."""
    edges = topo.edge_list()
    n_kill = int(np.floor(fraction * len(edges)))
    kill = rng.choice(len(edges), size=n_kill, replace=False)
    return edges[kill]


def failure_sample(topo: Topology, fraction: float, rng: np.random.Generator
                   ) -> np.ndarray:
    """Remove floor(fraction * |E|) random undirected edges; returns adj."""
    return masked_adjacency(topo.adj, failure_edge_sample(topo, fraction, rng))


def _connected(adj: np.ndarray) -> bool:
    n_comp, _ = csgraph.connected_components(sp.csr_matrix(adj),
                                             directed=False)
    return n_comp == 1


def _scipy_metrics(adj: np.ndarray):
    if not _connected(adj):
        return False, np.inf, np.inf
    d = csgraph.shortest_path(sp.csr_matrix(adj), method="D",
                              unweighted=True, directed=False)
    n = adj.shape[0]
    return True, float(d.max()), float(d.sum() / (n * (n - 1)))


def _kernel_metrics(adj_batch: np.ndarray):
    """Batched metrics via the Pallas min-plus APSP kernel."""
    n = adj_batch.shape[-1]
    d = np.asarray(apsp(adj_batch, max_diameter=n))
    reachable = d < 1e37
    out = []
    for i in range(adj_batch.shape[0]):
        di = d[i]
        if not reachable[i].all():
            out.append((False, np.inf, np.inf))
        else:
            out.append((True, float(di.max()),
                        float(di.sum() / (n * (n - 1)))))
    return out


def metric_after_failures(topo: Topology, fraction: float, metric: Metric,
                          n_samples: int, seed: int = 0,
                          engine: str = "scipy",
                          base_diameter: Optional[float] = None,
                          base_avgpath: Optional[float] = None) -> float:
    """Fraction of samples that SURVIVE the metric threshold.

    Baselines are computed lazily and only for what `metric` actually
    uses: 'disconnect' needs none, 'diameter' only the base diameter,
    'avgpath' only the base average path length."""
    rng = np.random.default_rng(seed)
    if ((metric == "diameter" and base_diameter is None)
            or (metric == "avgpath" and base_avgpath is None)):
        ok, bd, bp = _scipy_metrics(topo.adj)
        assert ok, "baseline topology disconnected"
        base_diameter = bd if base_diameter is None else base_diameter
        base_avgpath = bp if base_avgpath is None else base_avgpath

    samples = [failure_sample(topo, fraction, rng) for _ in range(n_samples)]
    if engine == "kernel":
        results = _kernel_metrics(np.stack(samples))
    else:
        results = [_scipy_metrics(a) for a in samples]

    ok_count = 0
    for connected, diam, avgp in results:
        if metric == "disconnect":
            ok_count += connected
        elif metric == "diameter":
            ok_count += connected and diam <= base_diameter + 2
        else:
            ok_count += connected and avgp <= base_avgpath + 1
    return ok_count / n_samples


def _fraction_sweep(fractions: np.ndarray,
                    evaluate: Callable[[float], object],
                    stop: Optional[Callable[[object], bool]] = None
                    ) -> Dict[float, object]:
    """Shared sweep driver: evaluate each fraction in ascending order,
    optionally stopping early.  Keys are rounded to the 5%-grid style."""
    out: Dict[float, object] = {}
    for f in np.sort(np.asarray(fractions, dtype=np.float64)):
        val = evaluate(float(f))
        out[round(float(f), 2)] = val
        if stop is not None and stop(val):
            break
    return out


def resilience_sweep(topo: Topology, metric: Metric = "disconnect",
                     n_samples: int = 20, seed: int = 0,
                     engine: str = "scipy",
                     fractions: Optional[np.ndarray] = None
                     ) -> Dict[float, float]:
    """Survival rate at each failure fraction (5% increments, paper style).

    Stops at the first fraction with rate 0.0 (included in the dict);
    consumers must treat absent larger fractions as failed — see the
    module docstring and `max_tolerated_fraction`."""
    if fractions is None:
        fractions = np.arange(0.05, 1.0, 0.05)
    if metric == "disconnect":
        assert _connected(topo.adj), "baseline topology disconnected"
        bd = bp = None              # baselines unused by this metric
    else:
        ok, bd, bp = _scipy_metrics(topo.adj)
        assert ok, "baseline topology disconnected"

    def evaluate(f: float) -> float:
        return metric_after_failures(topo, f, metric, n_samples,
                                     seed=seed + int(f * 1000), engine=engine,
                                     base_diameter=bd, base_avgpath=bp)

    return _fraction_sweep(fractions, evaluate, stop=lambda r: r == 0.0)


def max_tolerated_fraction(sweep: Dict[float, float],
                           threshold: float = 0.5) -> float:
    """Largest tested fraction f such that EVERY tested fraction <= f has
    survival rate >= threshold (the Table III number).

    Scans in ascending order and stops at the first sub-threshold
    fraction, so non-monotone rebounds above it do not count, and the
    fractions `resilience_sweep` omitted after its early stop (all
    larger than a rate-0.0 fraction) are correctly treated as failed."""
    best = 0.0
    for f in sorted(sweep):
        if sweep[f] >= threshold:
            best = f
        else:
            break
    return best


def routed_resilience_sweep(topo: Topology, n_samples: int = 10,
                            seed: int = 0, use_pallas: bool = True,
                            fractions: Optional[np.ndarray] = None,
                            channel_load: bool = False
                            ) -> Dict[float, Dict[str, float]]:
    """Routed Table III: per failure fraction, aggregate MIN-routing
    metrics over `n_samples` masks — the mean reroute success rate, the
    mean/max path stretch over still-reachable pairs, and the fraction
    of samples whose fabric stays fully routable ('survival', the
    routed analogue of the 'disconnect' rate).

    Distances for all samples of a fraction come from ONE batched
    min-plus APSP kernel call.  `channel_load=True` additionally walks
    per-sample MIN routes for the mean channel-load inflation (python
    loop — use on small networks / few samples)."""
    if fractions is None:
        fractions = np.arange(0.05, 0.55, 0.05)
    n = topo.n_routers
    off = ~np.eye(n, dtype=bool)
    n_pairs = n * (n - 1)
    base = build_routing(topo, use_pallas=use_pallas)
    base_dist = np.maximum(base.dist.astype(np.float64), 1.0)

    def evaluate(f: float) -> Dict[str, float]:
        rng = np.random.default_rng(seed + int(f * 1000))
        masks = [failure_edge_sample(topo, f, rng) for _ in range(n_samples)]
        adjs = np.stack([masked_adjacency(topo.adj, fe) for fe in masks])
        d = np.asarray(apsp(adjs, max_diameter=n, use_pallas=use_pallas))
        reach = (d < 1e37) & off[None]
        success = reach.sum(axis=(1, 2)) / n_pairs           # [S]
        stretch = np.where(reach, d / base_dist[None], np.nan)
        any_reach = bool(reach.any())
        out = dict(
            reroute_success=float(success.mean()),
            survival=float((success == 1.0).mean()),
            mean_stretch=(float(np.nanmean(stretch)) if any_reach
                          else float("inf")),
            max_stretch=(float(np.nanmax(stretch)) if any_reach
                         else float("inf")),
        )
        if channel_load:
            infl = [routed_resiliency_metrics(
                        topo, fe, base_rt=base,
                        use_pallas=use_pallas).load_inflation
                    for fe in masks]
            out["load_inflation"] = float(np.mean(infl))
        return out

    return _fraction_sweep(fractions, evaluate)
