"""Resiliency analyses under random link failures (paper §III-D).

Three metrics, each reported as the maximum fraction of links that can be
removed while the network (majority of samples) still satisfies:
  - 'disconnect':  stays connected                       (§III-D1, Table III)
  - 'diameter':    diameter <= original + 2              (§III-D2)
  - 'avgpath':     average path length <= original + 1   (§III-D3)

Engines: 'scipy' (C BFS — large networks), 'kernel' (batched Pallas
min-plus APSP — exercises the TPU path, used for small networks/tests).
"""

from __future__ import annotations

from typing import Dict, Literal, Optional

import numpy as np
import scipy.sparse as sp
import scipy.sparse.csgraph as csgraph

from ..kernels import apsp
from .topology import Topology

__all__ = ["failure_sample", "metric_after_failures", "resilience_sweep",
           "max_tolerated_fraction"]

Metric = Literal["disconnect", "diameter", "avgpath"]


def failure_sample(topo: Topology, fraction: float, rng: np.random.Generator
                   ) -> np.ndarray:
    """Remove floor(fraction * |E|) random undirected edges; returns adj."""
    edges = topo.edge_list()
    n_kill = int(np.floor(fraction * len(edges)))
    kill = rng.choice(len(edges), size=n_kill, replace=False)
    adj = topo.adj.copy()
    e = edges[kill]
    adj[e[:, 0], e[:, 1]] = False
    adj[e[:, 1], e[:, 0]] = False
    return adj


def _scipy_metrics(adj: np.ndarray):
    g = sp.csr_matrix(adj)
    n_comp, _ = csgraph.connected_components(g, directed=False)
    if n_comp > 1:
        return False, np.inf, np.inf
    d = csgraph.shortest_path(g, method="D", unweighted=True, directed=False)
    n = adj.shape[0]
    return True, float(d.max()), float(d.sum() / (n * (n - 1)))


def _kernel_metrics(adj_batch: np.ndarray):
    """Batched metrics via the Pallas min-plus APSP kernel."""
    n = adj_batch.shape[-1]
    d = np.asarray(apsp(adj_batch, max_diameter=n))
    reachable = d < 1e37
    out = []
    for i in range(adj_batch.shape[0]):
        di = d[i]
        if not reachable[i].all():
            out.append((False, np.inf, np.inf))
        else:
            out.append((True, float(di.max()),
                        float(di.sum() / (n * (n - 1)))))
    return out


def metric_after_failures(topo: Topology, fraction: float, metric: Metric,
                          n_samples: int, seed: int = 0,
                          engine: str = "scipy",
                          base_diameter: Optional[float] = None,
                          base_avgpath: Optional[float] = None) -> float:
    """Fraction of samples that SURVIVE the metric threshold."""
    rng = np.random.default_rng(seed)
    if metric in ("diameter", "avgpath") and (base_diameter is None
                                              or base_avgpath is None):
        ok, base_diameter, base_avgpath = _scipy_metrics(topo.adj)
        assert ok

    samples = [failure_sample(topo, fraction, rng) for _ in range(n_samples)]
    if engine == "kernel":
        results = _kernel_metrics(np.stack(samples))
    else:
        results = [_scipy_metrics(a) for a in samples]

    ok_count = 0
    for connected, diam, avgp in results:
        if metric == "disconnect":
            ok_count += connected
        elif metric == "diameter":
            ok_count += connected and diam <= base_diameter + 2
        else:
            ok_count += connected and avgp <= base_avgpath + 1
    return ok_count / n_samples


def resilience_sweep(topo: Topology, metric: Metric = "disconnect",
                     n_samples: int = 20, seed: int = 0,
                     engine: str = "scipy",
                     fractions: Optional[np.ndarray] = None
                     ) -> Dict[float, float]:
    """Survival rate at each failure fraction (5% increments, paper style)."""
    if fractions is None:
        fractions = np.arange(0.05, 1.0, 0.05)
    ok, bd, bp = _scipy_metrics(topo.adj)
    assert ok, "baseline topology disconnected"
    out = {}
    for f in fractions:
        rate = metric_after_failures(topo, float(f), metric, n_samples,
                                     seed=seed + int(f * 1000), engine=engine,
                                     base_diameter=bd, base_avgpath=bp)
        out[round(float(f), 2)] = rate
        if rate == 0.0:   # monotone enough in practice — stop early
            break
    return out


def max_tolerated_fraction(sweep: Dict[float, float],
                           threshold: float = 0.5) -> float:
    """Largest tested fraction whose survival rate >= threshold (the
    Table III number)."""
    best = 0.0
    for f in sorted(sweep):
        if sweep[f] >= threshold:
            best = f
    return best
