"""Physical datacenter layout (paper §VI-A, Fig 10).

Routers are grouped into racks; racks are placed on a near-square grid.
Intra-rack cables are electric (~1 m); inter-rack cables are optic with
length = Manhattan distance between racks (1 m rack pitch) + 2 m overhead
(paper §VI-B).

Slim Fly layout (Fig 10): for the 2q^2-router MMS graph, rack r (r in
[0, q)) merges subgroup (0, x=r, ·) with subgroup (1, m=r, ·) — q racks of
2q routers, every pair of racks joined by exactly 2q global channels, so
the datacenter is a fully-connected graph of identical racks.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .topology import Topology

__all__ = ["Layout", "make_layout"]

CABLE_OVERHEAD_M = 2.0       # paper §VI-B
INTRA_RACK_LEN_M = 1.0       # paper: avg intra-rack Manhattan distance
RACK_PITCH_M = 1.0           # racks are 1x1x2 m


@dataclasses.dataclass
class Layout:
    topo: Topology
    rack_of: np.ndarray          # [N_r] rack id per router
    rack_xy: np.ndarray          # [n_racks, 2] grid coordinates
    all_electric: bool = False   # folded tori need no fiber (paper §VI-B3a)

    @property
    def n_racks(self) -> int:
        return self.rack_xy.shape[0]

    def cable_lengths(self):
        """Returns (is_fiber [E], length_m [E]) aligned with topo.edge_list."""
        e = self.topo.edge_list()
        ra, rb = self.rack_of[e[:, 0]], self.rack_of[e[:, 1]]
        intra = ra == rb
        d = np.abs(self.rack_xy[ra] - self.rack_xy[rb]).sum(axis=1) * RACK_PITCH_M
        length = np.where(intra, INTRA_RACK_LEN_M, d + CABLE_OVERHEAD_M)
        if self.all_electric:
            return np.zeros(len(e), dtype=bool), length
        return ~intra, length

    def inter_rack_channels(self) -> np.ndarray:
        """[n_racks, n_racks] count of channels between rack pairs."""
        e = self.topo.edge_list()
        ra, rb = self.rack_of[e[:, 0]], self.rack_of[e[:, 1]]
        m = np.zeros((self.n_racks, self.n_racks), dtype=np.int64)
        np.add.at(m, (ra, rb), 1)
        np.add.at(m, (rb, ra), 1)
        np.fill_diagonal(m, 0)
        return m // 1


def _grid_positions(n_racks: int) -> np.ndarray:
    """Near-square grid (§VI-A step 4)."""
    x = max(1, int(np.floor(np.sqrt(n_racks))))
    y = int(np.ceil(n_racks / x))
    pos = [(i % x, i // x) for i in range(n_racks)]
    return np.array(pos[:n_racks], dtype=np.float64)


def make_layout(topo: Topology, routers_per_rack: Optional[int] = None
                ) -> Layout:
    """Topology-aware rack assignment; generic fallback packs
    `routers_per_rack` sequential routers per rack."""
    fam = topo.params.get("family", "generic")
    n = topo.n_routers

    if fam == "slimfly":
        q = topo.params["q"]
        # router (s, a, b) -> index s*q^2 + a*q + b; rack = a (merges the
        # subgroup pair with the same a), Fig 10 step 3.
        rack_of = (np.arange(n) % (q * q)) // q
        n_racks = q
    elif fam == "dragonfly":
        a = topo.params["a"]
        rack_of = np.arange(n) // a
        n_racks = topo.params["g"]
    elif fam == "fattree3":
        # pods as racks; the core level forms extra racks in a central row
        p = topo.params["k"] // 2
        lvl = np.arange(n) // (p * p)
        pod = np.arange(n) % (p * p) // p
        rack_of = np.where(lvl < 2, pod, p + (np.arange(n) - 2 * p * p) // p)
        n_racks = 2 * p
    elif fam in ("fbf3", "fbf2"):
        c = topo.params["c"]
        rack_of = np.arange(n) // c        # a group (fixed i,j) per rack
        n_racks = n // c
    elif fam.startswith("torus"):
        # folded torus: all-electric (paper §VI-B3a)
        per = routers_per_rack or 32
        rack_of = np.arange(n) // per
        n_racks = int(np.ceil(n / per))
        return Layout(topo, rack_of.astype(np.int64),
                      _grid_positions(n_racks), all_electric=True)
    else:
        per = routers_per_rack or 32
        rack_of = np.arange(n) // per
        n_racks = int(np.ceil(n / per))

    return Layout(topo, rack_of.astype(np.int64), _grid_positions(n_racks))
