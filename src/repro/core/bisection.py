"""Bisection bandwidth estimation (paper §III-C, Fig 5c).

The paper approximates SF/DLN bisection with METIS; we use spectral
bisection (Fiedler vector split at median) + greedy Kernighan–Lin-style
refinement.  Both give an UPPER bound on the true minimum bisection; the
refinement tightens it.  Analytic values for the other topologies follow
the paper's table: HC/FT-3: N/2, tori: 2N/k', DF/FBF-3: ~N/4, LH: 3N/2.
"""

from __future__ import annotations

import numpy as np
import scipy.sparse as sp
import scipy.sparse.linalg as spla

from .topology import Topology

__all__ = ["bisection_channels", "analytic_bisection_bw"]


def _cut_size(adj: np.ndarray, side: np.ndarray) -> int:
    return int(adj[np.ix_(side, ~side)].sum())


def bisection_channels(topo: Topology, refine_iters: int = 200,
                       seed: int = 0) -> int:
    """Number of router-router channels crossing a balanced bisection
    (upper bound on the minimum)."""
    n = topo.n_routers
    a = sp.csr_matrix(topo.adj.astype(np.float64))
    deg = np.asarray(a.sum(axis=1)).ravel()
    lap = sp.diags(deg) - a
    try:
        vals, vecs = spla.eigsh(lap, k=2, which="SM", tol=1e-6,
                                maxiter=5000)
        fiedler = vecs[:, np.argsort(vals)[1]]
    except Exception:
        rng = np.random.default_rng(seed)
        fiedler = rng.standard_normal(n)
    order = np.argsort(fiedler)
    side = np.zeros(n, dtype=bool)
    side[order[: n // 2]] = True

    adj = topo.adj
    cut = _cut_size(adj, side)
    # greedy pairwise swaps (KL-lite)
    rng = np.random.default_rng(seed)
    for _ in range(refine_iters):
        i = rng.choice(np.nonzero(side)[0])
        j = rng.choice(np.nonzero(~side)[0])
        side[i] = False
        side[j] = True
        new_cut = _cut_size(adj, side)
        if new_cut < cut:
            cut = new_cut
        else:
            side[i] = True
            side[j] = False
    return cut


def analytic_bisection_bw(family: str, N: int, kprime: int = 0,
                          p: int = 1) -> float:
    """Endpoint-normalised bisection bandwidth in units of endpoint links
    (paper's Fig 5c y-axis is Gb/s; multiply by the link rate)."""
    if family in ("hypercube", "fattree3"):
        return N / 2
    if family.startswith("torus"):
        return 2 * N / max(kprime, 1)
    if family in ("dragonfly", "fbf3"):
        return (N + 2 * p * p - 1) / 4
    if family == "longhop":
        return 1.5 * N
    raise ValueError(family)
