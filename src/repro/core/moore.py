"""Moore bound and the construction-optimality comparisons (paper §II-A, Fig 5).

The Moore Bound is the maximum number of radix-k' routers a diameter-D
network can contain:  MB(k', D) = 1 + k' * sum_{i=0}^{D-1} (k'-1)^i.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "moore_bound",
    "mms_routers",
    "bdf_routers",
    "delorme_routers",
    "dragonfly_routers",
    "fbf_routers",
    "fattree2_routers",
]


def moore_bound(kprime: int, diameter: int) -> int:
    if kprime <= 1:
        return 1 + kprime
    return 1 + kprime * sum((kprime - 1) ** i for i in range(diameter))


# ---- router-count formulas used in Fig 5a/5b ------------------------------

def mms_routers(kprime: float) -> float:
    """SF MMS: N_r = 2 q^2 with k' = (3q - delta)/2 => N_r ~ 8/9 k'^2."""
    q = 2.0 * kprime / 3.0
    return 2.0 * q * q


def bdf_routers(kprime: float) -> float:
    """Bermond–Delorme–Fahri diameter-3 (paper §II-C)."""
    return (8.0 / 27.0) * kprime**3 - (4.0 / 9.0) * kprime**2 + (2.0 / 3.0) * kprime


def delorme_routers(kprime: float) -> float:
    """Delorme diameter-3: N_r = (v+1)^2 (v^2+1)^2 / ... with k' = (v+1)^2...

    Paper: N_r = (v+1)^2 (v^2+1)^2 and k' = (v+1)^2  -- hence with
    v = sqrt(k')-1:  N_r = k' * (v^2+1)^2."""
    v = np.sqrt(kprime) - 1.0
    return kprime * (v * v + 1.0) ** 2


def dragonfly_routers(kprime: float) -> float:
    """Balanced DF (a=2h, p=h): k' = a-1+h = 3h-1 => h=(k'+1)/3,
    N_r = a*g = a(a h + 1) = 2h(2h^2+1)."""
    h = (kprime + 1.0) / 3.0
    return 2.0 * h * (2.0 * h * h + 1.0)


def fbf_routers(kprime: float, levels: int) -> float:
    """Flattened butterfly with (levels) dims each of size c:
    k' = levels*(c-1)  =>  N_r = c^levels."""
    c = kprime / levels + 1.0
    return c**levels


def fattree2_routers(kprime: float) -> float:
    """Two-stage (2-level) fat tree / folded Clos with radix k':
    k'^2/2 edge+core routers, k'^2/4... we report routers reachable within
    D=2 supporting full bisection: N_r = 3 (k'/2)^2 is the 2-level Clos
    router count; endpoints = k'^2/2."""
    return 1.5 * (kprime / 2.0) ** 2
