"""Routing for Slim Fly and comparison topologies (paper §IV).

- RoutingTables: distance matrix (via the Pallas min-plus APSP kernel) and
  next-hop tables for MIN routing, plus full equal-cost next-hop sets for
  path-diversity / UGAL candidate generation.
- Valiant (VAL) path construction.
- Hop-indexed virtual-channel assignment (§IV-D, Gopal's scheme) and the
  channel-dependency-graph acyclicity check that *proves* deadlock freedom
  for a given (topology, path set, VC count).
- channel_load: average/max minimal-route load per directed channel, the
  quantity behind the balanced-concentration formula (§II-B2) and the
  topology-aware collective cost model (repro.dist.topology_aware).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..kernels import apsp
from .topology import Topology, masked_adjacency, normalize_failed_edges

__all__ = [
    "UNREACH",
    "RoutingTables",
    "build_routing",
    "valiant_path",
    "assign_vcs",
    "channel_dependency_graph",
    "is_deadlock_free",
    "channel_load_uniform",
    "analytic_channel_load",
    "RoutedMetrics",
    "routed_resiliency_metrics",
]

# Hop-distance sentinel for pairs disconnected by link failures.  Small
# enough that int16 holds it and that the int32 sum of two sentinels
# (UGAL's len_min/len_val arithmetic in the simulator) cannot overflow,
# large enough that no real path length reaches it.
UNREACH = np.int16(1 << 14)


@dataclasses.dataclass
class RoutingTables:
    topo: Topology
    dist: np.ndarray             # [N_r, N_r] int16 hops (UNREACH = cut off)
    next_hop: np.ndarray         # [N_r, N_r] int32 deterministic MIN next hop
    next_hops_all: List[List[np.ndarray]] | None  # equal-cost sets (optional)
    # live adjacency the tables were computed on (== topo.adj unless a
    # failure mask was applied) and the mask itself ([K, 2] or None).
    adj: Optional[np.ndarray] = None
    failed_edges: Optional[np.ndarray] = None

    def __post_init__(self):
        if self.adj is None:
            self.adj = self.topo.adj

    @property
    def reachable(self) -> np.ndarray:
        """[N_r, N_r] bool: pairs with a surviving route."""
        return self.dist < UNREACH

    def min_path(self, s: int, d: int) -> List[int]:
        """Deterministic minimal path (router sequence, inclusive)."""
        assert self.dist[s, d] < UNREACH, f"no route {s} -> {d}"
        path = [s]
        cur = s
        while cur != d:
            cur = int(self.next_hop[cur, d])
            path.append(cur)
            assert len(path) <= self.dist[s, d] + 1
        return path

    def min_paths_all(self, s: int, d: int) -> List[List[int]]:
        """All shortest paths (for path-diversity analysis; D <= 2 graphs)."""
        if s == d:
            return [[s]]
        if self.adj[s, d]:
            return [[s, d]]
        if self.dist[s, d] >= UNREACH:
            return []
        mids = np.nonzero(self.adj[s] & self.adj[d])[0]
        if len(mids) and self.dist[s, d] == 2:
            return [[s, int(m), d] for m in mids]
        # fall back to generic DFS along decreasing distance
        out = []
        for n in np.nonzero(self.adj[s])[0]:
            if self.dist[n, d] == self.dist[s, d] - 1:
                out.extend([[s] + rest for rest in self.min_paths_all(int(n), d)])
        return out


def build_routing(topo: Topology, use_pallas: bool = True,
                  equal_cost_sets: bool = False,
                  failed_edges=None) -> RoutingTables:
    """Distance/next-hop tables; with `failed_edges` (see DESIGN.md §8)
    the tables are computed on the masked adjacency: routes re-converge
    around dead links, disconnected pairs get dist = UNREACH and
    next_hop = -1 instead of tripping the connectivity assert."""
    n = topo.n_routers
    adj = topo.adj
    if failed_edges is not None:
        failed_edges = normalize_failed_edges(failed_edges, topo)
        adj = masked_adjacency(adj, failed_edges)
    max_d = topo.params.get("diameter_hint", min(n, 64))
    if failed_edges is not None and len(failed_edges):
        max_d = n                  # failures can exceed the healthy diameter
    d = np.asarray(apsp(adj, max_diameter=max_d, use_pallas=use_pallas))
    if failed_edges is None:
        assert (d < 1e37).all(), "disconnected topology"
    dist = np.where(d < 1e37, d, float(UNREACH)).astype(np.int16)

    # next_hop[r, t] = lowest-index neighbor n of r with dist[n,t] = dist[r,t]-1
    next_hop = np.full((n, n), -1, dtype=np.int32)
    for r in range(n):
        nbrs = np.nonzero(adj[r])[0]                      # [deg]
        if len(nbrs) == 0:                 # router fully cut off by mask
            next_hop[r, r] = r
            continue
        # dist from each neighbor to every target: [deg, n]
        dn = dist[nbrs, :]
        good = dn == (dist[r, :][None, :] - 1)            # [deg, n]
        first = np.argmax(good, axis=0)                   # lowest index
        has = good.any(axis=0)
        next_hop[r, has] = nbrs[first[has]]
        next_hop[r, r] = r

    all_sets = None
    if equal_cost_sets:
        all_sets = []
        for r in range(n):
            nbrs = np.nonzero(adj[r])[0]
            dn = dist[nbrs, :]
            good = dn == (dist[r, :][None, :] - 1)
            all_sets.append([nbrs[good[:, t]] for t in range(n)])
    return RoutingTables(topo=topo, dist=dist, next_hop=next_hop,
                         next_hops_all=all_sets, adj=adj,
                         failed_edges=failed_edges)


def valiant_path(rt: RoutingTables, s: int, d: int, r_inter: int) -> List[int]:
    """VAL (§IV-B): minimal path s -> r_inter, then r_inter -> d."""
    first = rt.min_path(s, r_inter)
    second = rt.min_path(r_inter, d)
    return first + second[1:]


def assign_vcs(path: Sequence[int]) -> List[int]:
    """§IV-D: hop i uses VC i (2 VCs suffice for MIN on D=2, 4 for VAL)."""
    return list(range(len(path) - 1))


def channel_dependency_graph(paths: Sequence[Sequence[int]],
                             n_routers: int,
                             vcs_of: Optional[Sequence[Sequence[int]]] = None
                             ) -> Tuple[np.ndarray, int]:
    """Build the CDG over (directed channel, VC) nodes for a path set.

    ``vcs_of``, when given, supplies the per-hop VC list of each path
    (len(path) - 1 entries) — e.g. the ENGINE's clamped assignment
    ``min(vc_class + hop, V - 1)`` for explicit-path collective
    policies, where VC reuse past V hops can close cycles that the
    unclamped hop-indexed scheme provably cannot.  Default: the
    unclamped hop-indexed assignment (`assign_vcs`).

    Node id for channel (u -> v) on vc: vc * N_r^2 + u * N_r + v (dense ids,
    sparse usage)."""
    deps = set()
    max_vc = 0
    for pi, path in enumerate(paths):
        vcs = assign_vcs(path) if vcs_of is None else list(vcs_of[pi])
        assert len(vcs) == len(path) - 1, (len(vcs), len(path))
        if vcs:
            max_vc = max(max_vc, max(vcs))
        for i in range(len(path) - 2):
            u, v, w = path[i], path[i + 1], path[i + 2]
            a = vcs[i] * n_routers * n_routers + u * n_routers + v
            b = vcs[i + 1] * n_routers * n_routers + v * n_routers + w
            deps.add((a, b))
    n_nodes = (max_vc + 1) * n_routers * n_routers
    edges = np.array(sorted(deps), dtype=np.int64).reshape(-1, 2)
    return edges, n_nodes


def is_deadlock_free(paths: Sequence[Sequence[int]], n_routers: int,
                     vcs_of: Optional[Sequence[Sequence[int]]] = None
                     ) -> bool:
    """Kahn topological sort on the CDG: acyclic <=> deadlock-free under
    the given VC assignment (hop-indexed when ``vcs_of`` is omitted)."""
    edges, _ = channel_dependency_graph(paths, n_routers, vcs_of)
    if len(edges) == 0:
        return True
    nodes, inv = np.unique(edges, return_inverse=True)
    e = inv.reshape(-1, 2)
    n = len(nodes)
    indeg = np.zeros(n, dtype=np.int64)
    np.add.at(indeg, e[:, 1], 1)
    out_lists: List[List[int]] = [[] for _ in range(n)]
    for a, b in e:
        out_lists[a].append(b)
    stack = list(np.nonzero(indeg == 0)[0])
    seen = 0
    while stack:
        v = stack.pop()
        seen += 1
        for w in out_lists[v]:
            indeg[w] -= 1
            if indeg[w] == 0:
                stack.append(w)
    return seen == n


def channel_load_uniform(rt: RoutingTables, p: Optional[int] = None
                         ) -> Tuple[float, float]:
    """Empirical (avg, max) channel load under all-to-all uniform traffic
    with deterministic MIN routing (§II-B2).  Load = number of routes using
    each directed channel, normalised by p^2 endpoint pairs per router pair.
    Returns loads in units of routes per channel for p endpoints/router."""
    topo = rt.topo
    n = topo.n_routers
    p = p if p is not None else topo.p
    adj = rt.adj                     # live adjacency (mask-aware)
    load = np.zeros((n, n), dtype=np.float64)
    # D <= 2 fast path: direct edges get 1, two-hop routes via next_hop
    for s in range(n):
        t_direct = np.nonzero(adj[s])[0]
        load[s, t_direct] += 1.0
        t_two = np.nonzero(rt.dist[s] == 2)[0]
        mids = rt.next_hop[s, t_two]
        np.add.at(load, (np.full_like(mids, s), mids), 1.0)
        np.add.at(load, (mids, t_two), 1.0)
        # distances > 2: walk (generic topologies); unreachable pairs
        # (failure mask) simply contribute no routes
        t_far = np.nonzero((rt.dist[s] > 2) & (rt.dist[s] < UNREACH))[0]
        for t in t_far:
            path = rt.min_path(s, int(t))
            for u, v in zip(path[:-1], path[1:]):
                load[u, v] += 1.0
    chan = load[adj]                 # only live physical channels
    scale = p * p                    # p^2 endpoint pairs per router pair
    return float(chan.mean() * scale), float(chan.max() * scale)


def analytic_channel_load(kprime: int, n_r: int, p: int) -> float:
    """Paper's closed form: l = (2 N_r - k' - 2) p^2 / k'."""
    return (2 * n_r - kprime - 2) * p * p / kprime


@dataclasses.dataclass(frozen=True)
class RoutedMetrics:
    """Routed view of §III-D: what MIN routing delivers on a degraded
    fabric (cf. Blach et al. 2023's operational resiliency criteria)."""
    n_failed: int                   # undirected links removed
    connected: bool                 # every router pair still reachable
    reroute_success: float          # reachable fraction of ordered s != d pairs
    mean_stretch: float             # mean dist_failed / dist_healthy (reachable)
    max_stretch: float
    load_inflation: float           # mean live-channel load / healthy mean
    max_load_inflation: float       # max live-channel load / healthy max


def routed_resiliency_metrics(topo: Topology, failed_edges,
                              base_rt: Optional[RoutingTables] = None,
                              use_pallas: bool = False) -> RoutedMetrics:
    """Reroute success / path stretch / channel-load inflation of MIN
    routing re-converged on the masked adjacency, vs the healthy tables.

    A zero-length mask reproduces the healthy numbers exactly
    (stretch = inflation = 1, success = 1)."""
    fe = normalize_failed_edges(failed_edges, topo)
    base_rt = base_rt or build_routing(topo, use_pallas=use_pallas)
    rt = build_routing(topo, use_pallas=use_pallas, failed_edges=fe)

    n = topo.n_routers
    off = ~np.eye(n, dtype=bool)
    reach = rt.reachable & off
    n_pairs = n * (n - 1)
    success = float(reach.sum() / n_pairs)

    if reach.any():
        stretch = (rt.dist[reach].astype(np.float64)
                   / np.maximum(base_rt.dist[reach], 1).astype(np.float64))
        mean_stretch, max_stretch = float(stretch.mean()), float(stretch.max())
    else:
        mean_stretch = max_stretch = float("inf")

    base_avg, base_max = channel_load_uniform(base_rt)
    avg, mx = channel_load_uniform(rt)
    return RoutedMetrics(
        n_failed=len(fe),
        connected=bool(reach.sum() == n_pairs),
        reroute_success=success,
        mean_stretch=mean_stretch,
        max_stretch=max_stretch,
        load_inflation=float(avg / base_avg),
        max_load_inflation=float(mx / base_max),
    )
