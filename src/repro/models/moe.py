"""Mixture-of-Experts layer with scatter-based token dispatch.

Capacity-bounded top-k routing (Switch/GShard semantics) implemented with
one-hot-cumsum position assignment + scatter into per-expert buffers, then
batched expert matmuls.

Two distributed layouts (chosen by the launcher via `layout`):
  - expert-parallel (EP): n_experts divides the tp axis — the buffer's
    expert dim is tp-sharded; GSPMD emits the canonical MoE all-to-all
    at the scatter/gather boundaries (llama4-maverick: 128e / 16).
  - group-local: n_experts < tp size (mixtral: 8e / 16) — tokens are
    dispatched LOCALLY within each data shard (G groups = dp size, each
    with its own capacity), expert weights replicate over data (FSDP)
    and shard d_ff over tp.  No cross-device dispatch at all; the only
    collectives are the FSDP weight gathers and the TP partial-sum
    all-reduce — this removed a ~500 GB/device dense scatter all-reduce
    in the mixtral train cell (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["moe_layer", "moe_param_shapes"]


def moe_param_shapes(d_model: int, d_ff: int, n_experts: int,
                     shared_expert: bool):
    shapes = dict(
        router=(d_model, n_experts),
        w_gate=(n_experts, d_model, d_ff),
        w_up=(n_experts, d_model, d_ff),
        w_down=(n_experts, d_ff, d_model),
    )
    if shared_expert:
        shapes.update(sh_gate=(d_model, d_ff), sh_up=(d_model, d_ff),
                      sh_down=(d_ff, d_model))
    return shapes


def _constrain(t, spec_entries):
    from jax.sharding import PartitionSpec as PS
    try:
        return jax.lax.with_sharding_constraint(t, PS(*spec_entries))
    except Exception:
        return t


def moe_layer(x, params, *, top_k: int, capacity_factor: float = 1.25,
              shared_expert: bool = False, layout=None):
    """x: [B, S, D] -> [B, S, D].

    Dropped tokens (over capacity) pass through with zero expert output —
    the residual stream carries them (standard Switch behaviour).

    layout: None (no constraints) or (dp_axes, tp_axis, ep, groups).
    """
    B, S, D = x.shape
    E = params["router"].shape[1]
    T = B * S

    dp_axes, tp, ep, groups = (None, None, None, 1)
    if layout is not None:
        dp_axes, tp, ep, groups = layout
        groups = max(1, groups or 1)
        if T % groups != 0:
            groups = 1
    dp_e = None
    if dp_axes:
        dp_e = tuple(dp_axes) if len(dp_axes) > 1 else dp_axes[0]

    G = groups
    Tg = T // G
    xt = x.reshape(G, Tg, D)

    logits = (xt.astype(jnp.float32)
              @ params["router"].astype(jnp.float32))          # [G, Tg, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)        # [G, Tg, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    C = int(max(1, -(-Tg * top_k // E) * capacity_factor))

    flat_e = expert_idx.reshape(G, Tg * top_k)                 # [G, Tg*k]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # [G, Tg*k, E]
    pos = jnp.cumsum(onehot, axis=1) - onehot
    pos_in_e = jnp.take_along_axis(pos, flat_e[..., None], 2)[..., 0]
    keep = pos_in_e < C

    # scatter tokens into [G, E, C, D] buffers (overflow dropped via OOB)
    src = jnp.repeat(xt, top_k, axis=1)                        # [G, Tg*k, D]
    e_idx = jnp.where(keep, flat_e, E)
    g_idx = jnp.arange(G)[:, None] * jnp.ones_like(e_idx)
    buf = jnp.zeros((G, E + 1, C, D), dtype=x.dtype)
    buf = buf.at[g_idx, e_idx, jnp.minimum(pos_in_e, C - 1)].add(
        src, mode="drop")
    buf = buf[:, :E]

    if layout is not None and ep is not None and tp:
        if ep:
            # EP: experts over tp, capacity slots over dp (G == 1)
            buf = _constrain(buf, (None, tp, dp_e, None))
        else:
            # group-local: groups ride the dp axes, dispatch stays local
            buf = _constrain(buf, (dp_e, None, None, None))

    # batched expert FFN: [G,E,C,D] x [E,D,F]
    wg = params["w_gate"].astype(x.dtype)
    wu = params["w_up"].astype(x.dtype)
    wd = params["w_down"].astype(x.dtype)
    h = jnp.einsum("gecd,edf->gecf", buf, wg)
    u = jnp.einsum("gecd,edf->gecf", buf, wu)
    if layout is not None and ep is not None and tp:
        hspec = ((None, tp, dp_e, None) if ep
                 else (dp_e, None, None, tp))     # TP on d_ff when local
        h = _constrain(h, hspec)
        u = _constrain(u, hspec)
    h = jax.nn.silu(h) * u
    y_buf = jnp.einsum("gecf,efd->gecd", h, wd)
    if layout is not None and ep is not None and tp:
        y_buf = _constrain(y_buf, (None, tp, dp_e, None) if ep
                           else (dp_e, None, None, None))

    # gather back and combine with gates (token-local in both layouts)
    gathered = y_buf[g_idx, jnp.minimum(flat_e, E - 1),
                     jnp.minimum(pos_in_e, C - 1)]             # [G, Tg*k, D]
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    weighted = gathered * gate_vals.reshape(G, -1)[..., None].astype(x.dtype)
    y = weighted.reshape(G, Tg, top_k, D).sum(axis=2)

    if shared_expert:
        sh = (jax.nn.silu(xt @ params["sh_gate"]) * (xt @ params["sh_up"])
              ) @ params["sh_down"]
        y = y + sh

    return y.reshape(B, S, D)
