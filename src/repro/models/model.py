"""Unified functional model covering all 10 assigned architectures.

Pure-functional JAX (no flax): params are nested dicts, every entry point
is jit/pjit-able.  Entry points:
  init_params(rng, cfg, dtype)                -> params
  forward(params, batch, cfg)                 -> logits   (small/smoke use)
  forward_hidden(params, batch, cfg)          -> final hidden states
  loss_fn(params, batch, cfg)                 -> scalar (seq-chunked CE)
  init_cache(cfg, batch, max_len, dtype)      -> cache
  prefill(params, batch, cfg, cache)          -> (last logits, cache)
  decode_step(params, tokens, cfg, cache)     -> (logits, cache)

Scale features:
  - cfg.scan_layers: lax.scan over the repeating layer unit (compile time
    and HLO size O(1) in depth; the scan unit is remat'ed with the
    dots_saveable policy — the standard scan+checkpoint training combo);
  - loss_fn/prefill never materialise [B, S, vocab] logits: the unembed
    matmul + log-softmax run over sequence chunks (cfg.loss_chunk).

batch dict: tokens [B, S] int32 (+ labels for train, + 'frames'/'patches'
stub embeddings [B, F, D] for audio/vlm frontends).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..configs.base import ModelConfig
from .layers import (attention_block, flash_attention, gated_mlp, rms_norm,
                     softcap)
from .moe import moe_layer, moe_param_shapes
from .ssm import (mamba2_block, mamba2_decode_step, mamba2_init_state,
                  mamba2_param_shapes)
from .xlstm import (mlstm_block, mlstm_decode_step, mlstm_init_state,
                    mlstm_param_shapes, slstm_block, slstm_decode_step,
                    slstm_init_state, slstm_param_shapes)

__all__ = ["init_params", "forward", "forward_hidden", "loss_fn",
           "init_cache", "prefill", "decode_step", "param_count",
           "param_shapes"]


def _use_scan(cfg: ModelConfig) -> bool:
    return cfg.scan_layers and not cfg.n_encoder_layers


def _constrain(x, cfg: ModelConfig, *dims):
    """Activation sharding constraint from launcher hints (no-op when no
    mesh axes are configured, e.g. CPU smoke tests).  dims entries:
    'dp' -> batch axes, 'tp' -> tensor axis, None -> replicated."""
    if not cfg.dp_axes and not cfg.tp_axis:
        return x
    from jax.sharding import PartitionSpec as PS
    spec = []
    for d in dims:
        if d == "dp" and cfg.dp_axes:
            spec.append(tuple(cfg.dp_axes) if len(cfg.dp_axes) > 1
                        else cfg.dp_axes[0])
        elif d == "tp" and cfg.tp_axis:
            spec.append(cfg.tp_axis)
        else:
            spec.append(None)
    try:
        return jax.lax.with_sharding_constraint(x, PS(*spec))
    except Exception:
        return x   # no ambient mesh


# =========================================================== param shapes ==
def _attn_shapes(cfg: ModelConfig) -> dict:
    D, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s = dict(wq=(D, H * Dh), wk=(D, Hkv * Dh), wv=(D, Hkv * Dh),
             wo=(H * Dh, D))
    if cfg.qk_norm:
        s.update(q_norm=(Dh,), k_norm=(Dh,))
    return s


def _mlp_shapes(cfg: ModelConfig) -> dict:
    return dict(w_gate=(cfg.d_model, cfg.d_ff), w_up=(cfg.d_model, cfg.d_ff),
                w_down=(cfg.d_ff, cfg.d_model))


def _layer_shapes(cfg: ModelConfig, spec: dict) -> dict:
    D = cfg.d_model
    ls: dict = dict(norm1=(D,))
    if spec["kind"] == "attn":
        ls["attn"] = _attn_shapes(cfg)
        ls["norm2"] = (D,)
        if spec["ffn"] == "moe":
            ls["moe"] = moe_param_shapes(D, cfg.d_ff, cfg.n_experts,
                                         cfg.shared_expert)
        elif spec["ffn"] == "dense":
            ls["mlp"] = _mlp_shapes(cfg)
    elif spec["kind"] == "mamba":
        ls["mamba"] = mamba2_param_shapes(D, cfg.n_ssm_heads,
                                          cfg.ssm_head_dim, cfg.d_state)
    elif spec["kind"] == "mlstm":
        ls["mlstm"] = mlstm_param_shapes(D, cfg.n_heads, cfg.hd)
    elif spec["kind"] == "slstm":
        ls["slstm"] = slstm_param_shapes(D, cfg.n_heads, cfg.hd)
    return ls


def param_shapes(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    shapes: dict = dict(embed=(cfg.vocab, D), final_norm=(D,))
    if not cfg.tie_embeddings:
        shapes["unembed"] = (D, cfg.vocab)

    specs = cfg.layer_kinds()
    if _use_scan(cfg):
        P, n_units, n_tail = cfg.scan_split()

        def stack(tree):
            return jax.tree.map(
                lambda s: (n_units,) + tuple(s), tree,
                is_leaf=lambda x: isinstance(x, tuple) and all(
                    isinstance(i, (int, np.integer)) for i in x))

        shapes["layers_stack"] = [stack(_layer_shapes(cfg, specs[j]))
                                  for j in range(P)] if n_units else []
        shapes["layers_tail"] = [_layer_shapes(cfg, s)
                                 for s in specs[n_units * P:]]
    else:
        shapes["layers"] = [_layer_shapes(cfg, s) for s in specs]

    if cfg.family == "hybrid" and cfg.attn_every:
        shapes["shared_attn"] = dict(
            norm1=(D,), attn=_attn_shapes(cfg), norm2=(D,),
            mlp=_mlp_shapes(cfg))
    if cfg.n_encoder_layers:
        shapes["encoder"] = [
            dict(norm1=(D,), attn=_attn_shapes(cfg), norm2=(D,),
                 mlp=_mlp_shapes(cfg))
            for _ in range(cfg.n_encoder_layers)]
        shapes["cross"] = [dict(norm=(D,), attn=_attn_shapes(cfg))
                           for _ in range(cfg.n_layers)]
        shapes["enc_final_norm"] = (D,)
    return shapes


def init_params(rng, cfg: ModelConfig, dtype=jnp.float32):
    shapes = param_shapes(cfg)
    is_shape = lambda x: isinstance(x, tuple) and all(
        isinstance(i, (int, np.integer)) for i in x)
    leaves, treedef = jax.tree.flatten(shapes, is_leaf=is_shape)
    keys = jax.random.split(rng, len(leaves))
    embed_shape = shapes["embed"]

    def make(key, shape):
        if len(shape) == 1:
            return jnp.zeros(shape, dtype)        # norm weights (1+w form)
        fan_in = shape[-2]
        scale = 0.02 if tuple(shape) == tuple(embed_shape) else fan_in ** -0.5
        return jax.random.normal(key, shape, dtype) * scale

    return jax.tree.unflatten(treedef, [make(k, s)
                                        for k, s in zip(keys, leaves)])


def param_count(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


def layer_params_at(params, cfg: ModelConfig, i: int):
    """Per-layer param view regardless of stacked/flat layout."""
    if not _use_scan(cfg):
        return params["layers"][i]
    P, n_units, _ = cfg.scan_split()
    if i < n_units * P:
        u, j = divmod(i, P)
        return jax.tree.map(lambda x: x[u], params["layers_stack"][j])
    return params["layers_tail"][i - n_units * P]


# ================================================================ forward ==
def _dense_ffn(x, lp, cfg):
    return gated_mlp(x, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                     lp["mlp"]["w_down"], act="gelu")


def _decoder_layer_full(x, lp, spec, cfg: ModelConfig, positions,
                        enc_out=None, cross_p=None, shared_p=None):
    """One decoder layer, full-sequence mode (train / prefill).
    Returns (x, stash) where stash holds prefill KV / final states."""
    stash = {}
    kind = spec["kind"]
    if kind == "attn":
        h, kv = attention_block(rms_norm(x, lp["norm1"]), lp["attn"],
                                cfg.attn_layer_cfg(window=spec["window"]),
                                positions)
        x = x + h
        stash["kv"] = kv
        if cross_p is not None:
            hc, _ = _cross_attention(rms_norm(x, cross_p["norm"]),
                                     cross_p["attn"], enc_out, cfg)
            x = x + hc
        h2 = rms_norm(x, lp["norm2"])
        if spec["ffn"] == "moe":
            x = x + moe_layer(h2, lp["moe"], top_k=cfg.top_k,
                              capacity_factor=cfg.capacity_factor,
                              shared_expert=cfg.shared_expert,
                              layout=(cfg.dp_axes, cfg.tp_axis, cfg.moe_ep,
                                      cfg.moe_groups))
        else:
            x = x + _dense_ffn(h2, lp, cfg)
    elif kind == "mamba":
        y, st = mamba2_block(rms_norm(x, lp["norm1"]), lp["mamba"],
                             cfg.ssm_layer_cfg(), return_state=True)
        x = x + y
        stash["ssm"] = st
        if spec.get("shared_attn") and shared_p is not None:
            h, kv = attention_block(rms_norm(x, shared_p["norm1"]),
                                    shared_p["attn"], cfg.attn_layer_cfg(),
                                    positions)
            x = x + h
            x = x + gated_mlp(rms_norm(x, shared_p["norm2"]),
                              shared_p["mlp"]["w_gate"],
                              shared_p["mlp"]["w_up"],
                              shared_p["mlp"]["w_down"])
            stash["shared_kv"] = kv
    elif kind == "mlstm":
        y, st = mlstm_block(rms_norm(x, lp["norm1"]), lp["mlstm"],
                            cfg.xlstm_layer_cfg(), return_state=True)
        x = x + y
        stash["mlstm"] = st
    elif kind == "slstm":
        y, st = slstm_block(rms_norm(x, lp["norm1"]), lp["slstm"],
                            cfg.xlstm_layer_cfg(), return_state=True)
        x = x + y
        stash["slstm"] = st
    return x, stash


def _cross_attention(x, ap, enc_out, cfg: ModelConfig, cached_kv=None):
    """Cross-attention to encoder output (whisper decoder)."""
    B, S, _ = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ ap["wq"]).reshape(B, S, H, Dh)
    if cached_kv is None:
        F = enc_out.shape[1]
        k = (enc_out @ ap["wk"]).reshape(B, F, Hkv, Dh)
        v = (enc_out @ ap["wv"]).reshape(B, F, Hkv, Dh)
    else:
        k, v = cached_kv
    out = flash_attention(q, k, v, causal=False, block=512)
    out = out.reshape(B, S, H * Dh) @ ap["wo"]
    return out, (k, v)


def _run_encoder(params, frames, cfg: ModelConfig):
    x = frames
    pos = jnp.arange(x.shape[1])[None]
    for lp in params["encoder"]:
        h, _ = attention_block(rms_norm(x, lp["norm1"]), lp["attn"],
                               cfg.attn_layer_cfg(causal=False), pos)
        x = x + h
        x = x + gated_mlp(rms_norm(x, lp["norm2"]), lp["mlp"]["w_gate"],
                          lp["mlp"]["w_up"], lp["mlp"]["w_down"])
    return rms_norm(x, params["enc_final_norm"])


def _embed_inputs(params, batch, cfg: ModelConfig):
    """Token embedding + frontend-stub concatenation (vlm)."""
    x = params["embed"][batch["tokens"]] * (cfg.d_model ** 0.5)
    n_front = 0
    if cfg.frontend == "vision_stub" and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
        n_front = batch["patches"].shape[1]
    return x, n_front


def forward_hidden(params, batch, cfg: ModelConfig,
                   collect_stash: bool = False):
    """Embeddings -> all decoder layers -> final norm.
    Returns (hidden [B, S_total, D], stashes | None, n_front)."""
    x, n_front = _embed_inputs(params, batch, cfg)
    x = _constrain(x, cfg, "dp", None, None)
    positions = jnp.arange(x.shape[1])[None]
    enc_out = None
    if cfg.n_encoder_layers:
        enc_out = _run_encoder(params, batch["frames"], cfg)
    shared_p = params.get("shared_attn")
    specs = cfg.layer_kinds()

    stashes = None
    if _use_scan(cfg):
        P, n_units, _ = cfg.scan_split()
        unit_specs = specs[:P]

        def unit(x, unit_params):
            stash_u = []
            for j, sp in enumerate(unit_specs):
                x, st = _decoder_layer_full(x, unit_params[j], sp, cfg,
                                            positions, shared_p=shared_p)
                stash_u.append(st)
            return x, tuple(stash_u)

        unit_ck = jax.checkpoint(
            unit, policy=jax.checkpoint_policies.dots_saveable)
        if n_units:
            x, stacked = lax.scan(unit_ck, x, params["layers_stack"])
        else:
            stacked = None
        tail_stash = []
        for j, lp in enumerate(params["layers_tail"]):
            x, st = _decoder_layer_full(x, lp, specs[n_units * P + j], cfg,
                                        positions, shared_p=shared_p)
            tail_stash.append(st)
        if collect_stash:
            stashes = []
            for i in range(cfg.n_layers):
                if i < n_units * P:
                    u, j = divmod(i, P)
                    stashes.append(jax.tree.map(lambda s: s[u], stacked[j]))
                else:
                    stashes.append(tail_stash[i - n_units * P])
    else:
        stashes = []
        for i, (lp, spec) in enumerate(zip(params["layers"], specs)):
            cross_p = params["cross"][i] if cfg.n_encoder_layers else None
            x, stash = _decoder_layer_full(x, lp, spec, cfg, positions,
                                           enc_out=enc_out, cross_p=cross_p,
                                           shared_p=shared_p)
            stashes.append(stash)

    x = rms_norm(x, params["final_norm"])
    return x, (stashes if collect_stash else None), n_front


def _unembed_matrix(params, cfg: ModelConfig):
    return (params["embed"].T if cfg.tie_embeddings else params["unembed"])


def forward(params, batch, cfg: ModelConfig):
    """Full logits [B, S_total, vocab] — smoke/test path (materialises
    the logits; production paths use loss_fn / prefill instead)."""
    x, _, _ = forward_hidden(params, batch, cfg)
    logits = x @ _unembed_matrix(params, cfg).astype(x.dtype)
    return softcap(logits, cfg.final_softcap)


def loss_fn(params, batch, cfg: ModelConfig):
    """Next-token CE with sequence-chunked unembed+logsoftmax: peak extra
    memory is [B, chunk, vocab] bf16 instead of [B, S, vocab] f32."""
    x, _, n_front = forward_hidden(params, batch, cfg)
    x = x[:, n_front:]
    labels = batch.get("labels", batch["tokens"])
    xs = x[:, :-1]
    tgt = labels[:, 1:]
    B, Sm1, D = xs.shape
    unembed = _unembed_matrix(params, cfg)

    chunk = min(cfg.loss_chunk, Sm1)
    n_chunks = Sm1 // chunk
    rem = Sm1 - n_chunks * chunk

    def chunk_nll(xc, tc):
        logits = xc @ unembed.astype(xc.dtype)
        logits = _constrain(logits, cfg, "dp", None, "tp")
        logits = softcap(logits, cfg.final_softcap).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, tc[..., None], -1)[..., 0]
        return (lse - picked).sum()

    total = jnp.float32(0.0)
    if n_chunks:
        xm = xs[:, : n_chunks * chunk].reshape(B, n_chunks, chunk, D)
        tm = tgt[:, : n_chunks * chunk].reshape(B, n_chunks, chunk)

        def body(acc, xt):
            xc, tc = xt
            return acc + chunk_nll(xc, tc), None

        total, _ = lax.scan(body, total,
                            (jnp.moveaxis(xm, 1, 0), jnp.moveaxis(tm, 1, 0)))
    if rem:
        total = total + chunk_nll(xs[:, n_chunks * chunk:],
                                  tgt[:, n_chunks * chunk:])
    return total / (B * Sm1)


# ================================================================ serving ==
def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               dtype=jnp.bfloat16):
    """Per-layer decode caches.  Attention layers get ring buffers sized
    min(window, max_len); SSM/xLSTM layers carry recurrent state."""
    B = batch_size
    Hkv, Dh = cfg.n_kv_heads, cfg.hd
    cache: dict = dict(layers=[], len=jnp.zeros((B,), jnp.int32))

    def kv(sz):
        return dict(k=jnp.zeros((B, Hkv, sz, Dh), dtype),
                    v=jnp.zeros((B, Hkv, sz, Dh), dtype),
                    len=jnp.zeros((B,), jnp.int32))

    for spec in cfg.layer_kinds():
        if spec["kind"] == "attn":
            sz = min(spec["window"] or max_len, max_len)
            c = dict(kv=kv(sz))
        elif spec["kind"] == "mamba":
            c = dict(ssm=mamba2_init_state(B, cfg.ssm_layer_cfg()))
            if spec.get("shared_attn"):
                c["shared_kv"] = kv(max_len)
        elif spec["kind"] == "mlstm":
            c = dict(mlstm=mlstm_init_state(B, cfg.xlstm_layer_cfg()))
        else:
            c = dict(slstm=slstm_init_state(B, cfg.xlstm_layer_cfg()))
        cache["layers"].append(c)
    if cfg.n_encoder_layers:
        cache["cross_kv"] = None     # filled by prefill
    return cache


def decode_step(params, tokens, cfg: ModelConfig, cache):
    """tokens [B, 1] -> (logits [B, 1, vocab], cache)."""
    B = tokens.shape[0]
    x = params["embed"][tokens] * (cfg.d_model ** 0.5)
    positions = cache["len"][:, None]

    new_layers = []
    for i, (spec, lc) in enumerate(zip(cfg.layer_kinds(), cache["layers"])):
        lp = layer_params_at(params, cfg, i)
        nc = dict(lc)
        if spec["kind"] == "attn":
            h, nkv = attention_block(
                rms_norm(x, lp["norm1"]), lp["attn"],
                cfg.attn_layer_cfg(window=spec["window"]), positions,
                cache=lc["kv"])
            x = x + h
            nc["kv"] = nkv
            if cfg.n_encoder_layers:
                cp = params["cross"][i]
                hc, _ = _cross_attention(rms_norm(x, cp["norm"]), cp["attn"],
                                         None, cfg,
                                         cached_kv=cache["cross_kv"][i])
                x = x + hc
            h2 = rms_norm(x, lp["norm2"])
            if spec["ffn"] == "moe":
                x = x + moe_layer(h2, lp["moe"], top_k=cfg.top_k,
                                  capacity_factor=cfg.capacity_factor,
                                  shared_expert=cfg.shared_expert,
                                  layout=(cfg.dp_axes, cfg.tp_axis,
                                          cfg.moe_ep, cfg.moe_groups))
            else:
                x = x + _dense_ffn(h2, lp, cfg)
        elif spec["kind"] == "mamba":
            y, st = mamba2_decode_step(rms_norm(x, lp["norm1"]), lp["mamba"],
                                       cfg.ssm_layer_cfg(), lc["ssm"])
            x = x + y
            nc["ssm"] = st
            if spec.get("shared_attn"):
                sp = params["shared_attn"]
                h, nkv = attention_block(rms_norm(x, sp["norm1"]), sp["attn"],
                                         cfg.attn_layer_cfg(), positions,
                                         cache=lc["shared_kv"])
                x = x + h
                x = x + gated_mlp(rms_norm(x, sp["norm2"]),
                                  sp["mlp"]["w_gate"], sp["mlp"]["w_up"],
                                  sp["mlp"]["w_down"])
                nc["shared_kv"] = nkv
        elif spec["kind"] == "mlstm":
            y, st = mlstm_decode_step(rms_norm(x, lp["norm1"]), lp["mlstm"],
                                      cfg.xlstm_layer_cfg(), lc["mlstm"])
            x = x + y
            nc["mlstm"] = st
        else:
            y, st = slstm_decode_step(rms_norm(x, lp["norm1"]), lp["slstm"],
                                      cfg.xlstm_layer_cfg(), lc["slstm"])
            x = x + y
            nc["slstm"] = st
        new_layers.append(nc)

    x = rms_norm(x, params["final_norm"])
    logits = softcap(x @ _unembed_matrix(params, cfg).astype(x.dtype),
                     cfg.final_softcap)
    new_cache = dict(cache, layers=new_layers, len=cache["len"] + 1)
    return logits, new_cache


def prefill(params, batch, cfg: ModelConfig, cache):
    """Run the prompt through the full forward, stash KV/states into the
    decode cache.  Returns (last-position logits, cache)."""
    x, stashes, n_front = forward_hidden(params, batch, cfg,
                                         collect_stash=True)
    S = batch["tokens"].shape[1] + n_front
    B = batch["tokens"].shape[0]
    last = x[:, -1:]
    logits = softcap(last @ _unembed_matrix(params, cfg).astype(x.dtype),
                     cfg.final_softcap)

    new_layers = []
    for spec, lc, stash in zip(cfg.layer_kinds(), cache["layers"], stashes):
        nc = dict(lc)
        if spec["kind"] == "attn":
            nc["kv"] = _stash_kv(lc["kv"], stash["kv"], S)
        elif spec["kind"] == "mamba":
            nc["ssm"] = stash["ssm"]
            if spec.get("shared_attn"):
                nc["shared_kv"] = _stash_kv(lc["shared_kv"],
                                            stash["shared_kv"], S)
        elif spec["kind"] == "mlstm":
            nc["mlstm"] = stash["mlstm"]
        else:
            nc["slstm"] = stash["slstm"]
        new_layers.append(nc)

    new_cache = dict(cache, layers=new_layers,
                     len=jnp.full((B,), S, jnp.int32))
    if cfg.n_encoder_layers:
        enc_out = _run_encoder(params, batch["frames"], cfg)
        ckv = []
        for cp in params["cross"]:
            F = enc_out.shape[1]
            k = (enc_out @ cp["attn"]["wk"]).reshape(B, F, cfg.n_kv_heads,
                                                     cfg.hd)
            v = (enc_out @ cp["attn"]["wv"]).reshape(B, F, cfg.n_kv_heads,
                                                     cfg.hd)
            ckv.append((k, v))
        new_cache["cross_kv"] = ckv
    return logits, new_cache


def _stash_kv(kv_cache, kv_new, S):
    """Write the last min(S, C) prefill keys/values into the ring cache."""
    k, v = kv_new                          # [B, S, Hkv, Dh]
    C = kv_cache["k"].shape[2]
    B = k.shape[0]
    k_t = jnp.swapaxes(k, 1, 2).astype(kv_cache["k"].dtype)
    v_t = jnp.swapaxes(v, 1, 2).astype(kv_cache["v"].dtype)
    if S <= C:
        ck = jax.lax.dynamic_update_slice(
            kv_cache["k"], k_t, (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            kv_cache["v"], v_t, (0, 0, 0, 0))
    else:
        # keep the last C positions; ring invariant: slot = pos % C
        last_k = k_t[:, :, S - C:]
        last_v = v_t[:, :, S - C:]
        roll = (S - C) % C
        ck = jnp.roll(last_k, shift=roll, axis=2)
        cv = jnp.roll(last_v, shift=roll, axis=2)
    return dict(k=ck, v=cv, len=jnp.full((B,), S, jnp.int32))
