"""Mamba2-style selective state-space (SSD) block — the zamba2 backbone.

Chunked linear-recurrence formulation (Dao & Gu 2024, simplified):
  h_t = exp(A * dt_t) h_{t-1} + dt_t * B_t x_t        (per head, d_state N)
  y_t = C_t^T h_t + D x_t
Scalar A per head (Mamba2's SSD restriction).  Prefill/train processes the
sequence in chunks: intra-chunk via cumulative-decay attention-like masks,
inter-chunk via a scan over [B, H, dh, N] states.  Decode is the one-step
recurrence against a cached state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["mamba2_scan", "mamba2_block", "mamba2_param_shapes",
           "mamba2_decode_step", "mamba2_init_state"]


def mamba2_param_shapes(d_model: int, n_heads: int, d_head: int,
                        d_state: int, expand: int = 2):
    d_inner = n_heads * d_head
    return dict(
        in_proj=(d_model, 2 * d_inner + 2 * d_state * n_heads + n_heads),
        a_log=(n_heads,),
        d_skip=(n_heads,),
        norm=(d_inner,),
        out_proj=(d_inner, d_model),
    )


def _split_proj(z, n_heads, d_head, d_state):
    d_inner = n_heads * d_head
    xz, rest = z[..., : 2 * d_inner], z[..., 2 * d_inner:]
    x_in, gate = xz[..., :d_inner], xz[..., d_inner:]
    bc, dt = rest[..., : 2 * d_state * n_heads], rest[..., 2 * d_state * n_heads:]
    b, c = jnp.split(bc, 2, axis=-1)
    return x_in, gate, b, c, dt


def mamba2_scan(x_in, b, c, dt, a_log, d_skip, *, chunk: int = 128,
                init_state=None, return_state: bool = False):
    """Chunked SSD scan.

    x_in: [B, S, H, P] (P = d_head); b, c: [B, S, H, N]; dt: [B, S, H].
    Returns y [B, S, H, P] (and final state [B, H, P, N] if requested).
    """
    B, S, H, P = x_in.shape
    N = b.shape[-1]
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        x_in = jnp.pad(x_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # dt -> -1e4 so softplus(dt) == 0: padded steps neither decay the
        # state (la = 0) nor inject into it (dt * x = 0) — the final state
        # equals the state at position S exactly.
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)), constant_values=-1e4)

    dt = jax.nn.softplus(dt.astype(jnp.float32))               # [B, S', H]
    a = -jnp.exp(a_log.astype(jnp.float32))                    # [H] (neg)
    la = dt * a[None, None, :]                                 # log decay
    xb = (x_in.astype(jnp.float32)
          * dt[..., None])                                     # dt * x

    # reshape into chunks: [B, nc, L, H, ...]
    L = chunk
    xc = xb.reshape(B, n_chunks, L, H, P)
    bc_ = b.reshape(B, n_chunks, L, H, N).astype(jnp.float32)
    cc = c.reshape(B, n_chunks, L, H, N).astype(jnp.float32)
    lac = la.reshape(B, n_chunks, L, H)

    cum = jnp.cumsum(lac, axis=2)                              # [B,nc,L,H]
    total = cum[:, :, -1]                                      # [B,nc,H]

    # ---- intra-chunk (causal "attention" with decay weights)
    # w[t, s] = exp(cum_t - cum_s) for s <= t.  The mask is applied to the
    # EXPONENT (not the result) so the masked entries cannot overflow and
    # poison the gradient (where-of-exp NaN trap).
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # [B,nc,L,L,H]
    causal = jnp.tril(jnp.ones((L, L), bool))
    diff = jnp.where(causal[None, None, :, :, None], diff, -1e30)
    w = jnp.exp(diff)
    scores = jnp.einsum("bklhn,bkshn->bklsh", cc, bc_)         # C_t . B_s
    y_intra = jnp.einsum("bklsh,bklsh,bkshp->bklhp",
                         scores, w, xc)

    # ---- inter-chunk: state carried across chunks
    # chunk-local state contribution: sum_s exp(cum_last - cum_s) B_s x_s
    decay_to_end = jnp.exp(total[:, :, None] - cum)            # [B,nc,L,H]
    state_add = jnp.einsum("bklh,bklhn,bklhp->bkhpn",
                           decay_to_end, bc_, xc)              # [B,nc,H,P,N]

    def scan_fn(h_prev, inp):
        tot, add = inp                                         # [B,H], [B,H,P,N]
        h_new = h_prev * jnp.exp(tot)[..., None, None] + add
        return h_new, h_prev                                   # emit PRE state

    h0 = (jnp.zeros((B, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    tot_t = jnp.moveaxis(total, 1, 0)                          # [nc,B,H]
    add_t = jnp.moveaxis(state_add, 1, 0)
    h_final, h_pre = lax.scan(scan_fn, h0, (tot_t, add_t))
    h_pre = jnp.moveaxis(h_pre, 0, 1)                          # [B,nc,H,P,N]

    # contribution of the carried state to each position
    decay_from_start = jnp.exp(cum)                            # [B,nc,L,H]
    y_inter = jnp.einsum("bklhn,bkhpn,bklh->bklhp",
                         cc, h_pre, decay_from_start)

    y = (y_intra + y_inter).reshape(B, n_chunks * L, H, P)[:, :S]
    y = y + x_in.reshape(B, n_chunks * L, H, P)[:, :S] \
        * d_skip.astype(jnp.float32)[None, None, :, None]
    if return_state:
        return y, h_final
    return y


def mamba2_block(x, params, cfg, init_state=None, return_state=False):
    """x: [B, S, D_model] -> [B, S, D_model] (+ final SSD state)."""
    H, P, N = cfg["n_ssm_heads"], cfg["ssm_head_dim"], cfg["d_state"]
    z = x @ params["in_proj"]
    x_in, gate, b, c, dt = _split_proj(z, H, P, N)
    B_, S, _ = x.shape
    x_in = x_in.reshape(B_, S, H, P)
    b = b.reshape(B_, S, H, N)
    c = c.reshape(B_, S, H, N)
    out = mamba2_scan(x_in, b, c, dt, params["a_log"], params["d_skip"],
                      init_state=init_state, return_state=return_state)
    y, h_final = out if return_state else (out, None)
    y = y.reshape(B_, S, H * P).astype(x.dtype)
    y = y * jax.nn.silu(gate)
    from .layers import rms_norm
    y = rms_norm(y, params["norm"])
    y = y @ params["out_proj"]
    return (y, h_final) if return_state else y


def mamba2_init_state(batch, cfg, dtype=jnp.float32):
    return jnp.zeros((batch, cfg["n_ssm_heads"], cfg["ssm_head_dim"],
                      cfg["d_state"]), dtype)


def mamba2_decode_step(x, params, cfg, state):
    """One-token recurrence.  x: [B, 1, D]; state [B, H, P, N]."""
    H, P, N = cfg["n_ssm_heads"], cfg["ssm_head_dim"], cfg["d_state"]
    z = x @ params["in_proj"]
    x_in, gate, b, c, dt = _split_proj(z, H, P, N)
    B_ = x.shape[0]
    x_in = x_in.reshape(B_, H, P).astype(jnp.float32)
    b = b.reshape(B_, H, N).astype(jnp.float32)
    c = c.reshape(B_, H, N).astype(jnp.float32)
    dt = jax.nn.softplus(dt.reshape(B_, H).astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a[None])                              # [B, H]
    state = (state * decay[..., None, None]
             + jnp.einsum("bhp,bhn,bh->bhpn", x_in, b, dt))
    y = jnp.einsum("bhn,bhpn->bhp", c, state)
    y = y + x_in * params["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B_, 1, H * P).astype(x.dtype)
    y = y * jax.nn.silu(gate.reshape(B_, 1, -1))
    from .layers import rms_norm
    y = rms_norm(y, params["norm"])
    return y @ params["out_proj"], state
