"""xLSTM blocks (Beck et al. 2024): mLSTM (matrix memory, parallelisable)
and sLSTM (scalar memory, strictly recurrent), for xlstm-1.3b.

mLSTM chunked form (mirrors the SSD trick): exponential input gate i,
sigmoid forget gate f, per-head matrix memory C [P, P] and normaliser
n [P]:
    C_t = f_t C_{t-1} + i_t v_t k_t^T
    n_t = f_t n_{t-1} + i_t k_t
    h_t = o_t * (q_t C_t) / max(|q_t . n_t|, 1)
Intra-chunk pairs are evaluated with cumulative-log-gate weights; the
inter-chunk state is carried by a lax.scan — O(S * chunk) memory.

sLSTM: lax.scan over time (no parallel form exists — the recurrent gate
matrices R forbid it; this is the paper's own trade-off).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["mlstm_block", "mlstm_param_shapes", "mlstm_init_state",
           "mlstm_decode_step", "slstm_block", "slstm_param_shapes",
           "slstm_init_state", "slstm_decode_step"]

_EXP_CLIP = 30.0


def mlstm_param_shapes(d_model: int, n_heads: int, d_head: int):
    d_inner = n_heads * d_head
    return dict(
        wq=(d_model, d_inner), wk=(d_model, d_inner), wv=(d_model, d_inner),
        w_if=(d_model, 2 * n_heads),          # input & forget gate projections
        w_o=(d_model, d_inner),               # output gate
        norm=(d_inner,),
        out_proj=(d_inner, d_model),
    )


def _gates(x, w_if, n_heads):
    g = x @ w_if                                            # [B,S,2H]
    li = g[..., :n_heads].astype(jnp.float32)               # log input gate
    lf = jax.nn.log_sigmoid(g[..., n_heads:].astype(jnp.float32))
    return li, lf


def mlstm_block(x, params, cfg, init_state=None, return_state=False,
                chunk: int = 128):
    """x: [B, S, D] -> [B, S, D].  State: (C [B,H,P,P], n [B,H,P])."""
    H, P = cfg["n_heads"], cfg["head_dim"]
    B, S, _ = x.shape
    scale = 1.0 / (P ** 0.5)
    q = (x @ params["wq"]).reshape(B, S, H, P).astype(jnp.float32) * scale
    k = (x @ params["wk"]).reshape(B, S, H, P).astype(jnp.float32)
    v = (x @ params["wv"]).reshape(B, S, H, P).astype(jnp.float32)
    li, lf = _gates(x, params["w_if"], H)                   # [B,S,H]

    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for t in (q, k, v))
        # padded steps must be state no-ops: input gate exp(-1e30) = 0
        # (no injection), forget gate log f = 0 => f = 1 (no decay).
        li = jnp.pad(li, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)), constant_values=0.0)
    L = chunk
    qc = q.reshape(B, n_chunks, L, H, P)
    kc = k.reshape(B, n_chunks, L, H, P)
    vc = v.reshape(B, n_chunks, L, H, P)
    lic = li.reshape(B, n_chunks, L, H)
    lfc = lf.reshape(B, n_chunks, L, H)

    cum = jnp.cumsum(lfc, axis=2)                           # [B,nc,L,H]
    total = cum[:, :, -1]

    # intra-chunk weights w[t,s] = exp(cum_t - cum_s + li_s), s <= t
    expo = (cum[:, :, :, None, :] - cum[:, :, None, :, :]
            + lic[:, :, None, :, :])
    causal = jnp.tril(jnp.ones((L, L), bool))
    expo = jnp.where(causal[None, None, :, :, None],
                     jnp.minimum(expo, _EXP_CLIP), -1e30)
    w = jnp.exp(expo)
    scores = jnp.einsum("bklhp,bkshp->bklsh", qc, kc)
    ws = w * scores
    num_intra = jnp.einsum("bklsh,bkshp->bklhp", ws, vc)
    den_intra = ws.sum(axis=3)                              # [B,nc,L,H]

    # inter-chunk state scan
    decay_to_end = jnp.exp(jnp.minimum(total[:, :, None] - cum + lic,
                                       _EXP_CLIP))          # [B,nc,L,H]
    C_add = jnp.einsum("bklh,bklhp,bklhq->bkhpq", decay_to_end, vc, kc)
    n_add = jnp.einsum("bklh,bklhp->bkhp", decay_to_end, kc)

    def scan_fn(carry, inp):
        Cp, np_ = carry
        tot, ca, na = inp
        d = jnp.exp(tot)[..., None, None]
        return (Cp * d + ca, np_ * jnp.exp(tot)[..., None] + na), (Cp, np_)

    C0 = (jnp.zeros((B, H, P, P), jnp.float32) if init_state is None
          else init_state[0].astype(jnp.float32))
    n0 = (jnp.zeros((B, H, P), jnp.float32) if init_state is None
          else init_state[1].astype(jnp.float32))
    (Cf, nf), (C_pre, n_pre) = lax.scan(
        scan_fn, (C0, n0),
        (jnp.moveaxis(total, 1, 0), jnp.moveaxis(C_add, 1, 0),
         jnp.moveaxis(n_add, 1, 0)))
    C_pre = jnp.moveaxis(C_pre, 0, 1)                       # [B,nc,H,P,P]
    n_pre = jnp.moveaxis(n_pre, 0, 1)

    carry_w = jnp.exp(jnp.minimum(cum, _EXP_CLIP))          # [B,nc,L,H]
    num_inter = jnp.einsum("bklh,bklhq,bkhpq->bklhp", carry_w, qc, C_pre)
    den_inter = jnp.einsum("bklh,bklhp,bkhp->bklh", carry_w, qc, n_pre)

    num = num_intra + num_inter
    den = den_intra + den_inter
    h = num / jnp.maximum(jnp.abs(den)[..., None], 1.0)
    h = h.reshape(B, n_chunks * L, H * P)[:, :S].astype(x.dtype)

    o = jax.nn.sigmoid(x @ params["w_o"])
    h = h * o
    from .layers import rms_norm
    h = rms_norm(h, params["norm"])
    y = h @ params["out_proj"]
    if return_state:
        return y, (Cf, nf)
    return y


def mlstm_init_state(batch, cfg, dtype=jnp.float32):
    H, P = cfg["n_heads"], cfg["head_dim"]
    return (jnp.zeros((batch, H, P, P), dtype),
            jnp.zeros((batch, H, P), dtype))


def mlstm_decode_step(x, params, cfg, state):
    """x: [B, 1, D]; state (C, n)."""
    H, P = cfg["n_heads"], cfg["head_dim"]
    B = x.shape[0]
    scale = 1.0 / (P ** 0.5)
    q = (x @ params["wq"]).reshape(B, H, P).astype(jnp.float32) * scale
    k = (x @ params["wk"]).reshape(B, H, P).astype(jnp.float32)
    v = (x @ params["wv"]).reshape(B, H, P).astype(jnp.float32)
    li, lf = _gates(x, params["w_if"], H)                   # [B,1,H]
    i_g = jnp.exp(jnp.minimum(li[:, 0], _EXP_CLIP))         # [B,H]
    f_g = jnp.exp(lf[:, 0])
    C, n = state
    C = C * f_g[..., None, None] + jnp.einsum("bhp,bhq,bh->bhpq", v, k, i_g)
    n = n * f_g[..., None] + k * i_g[..., None]
    num = jnp.einsum("bhq,bhpq->bhp", q, C)
    den = jnp.einsum("bhp,bhp->bh", q, n)
    h = num / jnp.maximum(jnp.abs(den)[..., None], 1.0)
    h = h.reshape(B, 1, H * P).astype(x.dtype)
    h = h * jax.nn.sigmoid(x @ params["w_o"])
    from .layers import rms_norm
    h = rms_norm(h, params["norm"])
    return h @ params["out_proj"], (C, n)


# ------------------------------------------------------------------ sLSTM --
def slstm_param_shapes(d_model: int, n_heads: int, d_head: int):
    d_inner = n_heads * d_head
    return dict(
        w_in=(d_model, 4 * d_inner),          # z, i, f, o pre-activations
        r_rec=(n_heads, d_head, 4 * d_head),  # block-diagonal recurrence
        norm=(d_inner,),
        out_proj=(d_inner, d_model),
    )


def slstm_init_state(batch, cfg, dtype=jnp.float32):
    H, P = cfg["n_heads"], cfg["head_dim"]
    z = jnp.zeros((batch, H, P), dtype)
    return (z, z, z)                           # (c, n, h)


def _slstm_cell(x_pre, state, r_rec, n_heads, d_head):
    """x_pre: [B, 4*H*P] input pre-activations; state (c, n, h)."""
    c, n, h = state
    B = x_pre.shape[0]
    rec = jnp.einsum("bhp,hpq->bhq", h, r_rec)              # [B,H,4P]
    pre = x_pre.reshape(B, n_heads, 4 * d_head) + rec
    z, i, f, o = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z)
    i = jnp.exp(jnp.minimum(i.astype(jnp.float32), _EXP_CLIP))
    f = jax.nn.sigmoid(f.astype(jnp.float32))
    o = jax.nn.sigmoid(o)
    c = f * c + i * z.astype(jnp.float32)
    n = f * n + i
    h_new = o * (c / jnp.maximum(n, 1.0)).astype(o.dtype)
    return (c, n, h_new)


def slstm_block(x, params, cfg, init_state=None, return_state=False):
    """Strictly sequential scan over time."""
    H, P = cfg["n_heads"], cfg["head_dim"]
    B, S, _ = x.shape
    x_pre = x @ params["w_in"]                               # [B,S,4HP]
    state = init_state or slstm_init_state(B, cfg)

    def step(st, xt):
        st = _slstm_cell(xt, st, params["r_rec"], H, P)
        return st, st[2]

    state, hs = lax.scan(step, state, jnp.moveaxis(x_pre, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H * P).astype(x.dtype)
    from .layers import rms_norm
    h = rms_norm(h, params["norm"])
    y = h @ params["out_proj"]
    if return_state:
        return y, state
    return y


def slstm_decode_step(x, params, cfg, state):
    H, P = cfg["n_heads"], cfg["head_dim"]
    B = x.shape[0]
    x_pre = (x @ params["w_in"]).reshape(B, -1)
    state = _slstm_cell(x_pre, state, params["r_rec"], H, P)
    h = state[2].reshape(B, 1, H * P).astype(x.dtype)
    from .layers import rms_norm
    h = rms_norm(h, params["norm"])
    return h @ params["out_proj"], state
