"""Shared building blocks for the model zoo: norms, RoPE, MLPs, and
memory-sane attention (blockwise-flash prefill in pure JAX + the Pallas
decode kernel for serving)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import decode_attention

NEG_INF = -2.0e38


# ----------------------------------------------------------------- norms --
def rms_norm(x, weight, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ------------------------------------------------------------------ RoPE --
def rope_angles(positions, head_dim: int, theta: float = 10_000.0):
    """positions [*, S] -> (cos, sin) [*, S, head_dim/2] (float32)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., S, H, D]; cos/sin [..., S, D/2] broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :] if x.ndim == cos.ndim + 1 else cos
    s = sin[..., None, :] if x.ndim == sin.ndim + 1 else sin
    # broadcast: x is [B,S,H,D]; cos [B,S,D/2] -> [B,S,1,D/2]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


# ------------------------------------------------------------------- MLP --
def gated_mlp(x, w_gate, w_up, w_down, act: str = "silu"):
    g = x @ w_gate
    u = x @ w_up
    if act == "silu":
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(g, approximate=True) * u
    return h @ w_down


# ------------------------------------------------------- flash attention --
def _seq_constrain(t, seq_axes, sq_dim: int):
    """Pin the Sq dim of a flash-attention carry to the tp axis (avoids
    the SPMD 'involuntary full rematerialization' resharding)."""
    if seq_axes is None:
        return t
    from jax.sharding import PartitionSpec as PS
    dp, tp = seq_axes
    spec = [None] * t.ndim
    if dp:
        spec[0] = tuple(dp) if len(dp) > 1 else dp[0]
    spec[sq_dim] = tp
    try:
        return jax.lax.with_sharding_constraint(t, PS(*spec))
    except Exception:
        return t


@functools.partial(jax.jit, static_argnames=("causal", "window", "block",
                                             "cap", "seq_axes"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block: int = 1024,
                    cap: Optional[float] = None, seq_axes=None):
    """Blockwise-online-softmax attention in pure JAX (lax.scan over KV
    blocks).  Never materialises the S x S score matrix — this is what
    makes the 32k prefill shapes compile inside HBM.

    q: [B, Sq, H, D]; k, v: [B, Skv, Hkv, D] (GQA: H = G * Hkv).
    causal assumes q occupies the LAST Sq positions of the Skv timeline.
    window: sliding-window size (attend to the last `window` positions).
    """
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / (D ** 0.5)
    q_off = Skv - Sq    # first q position in the kv timeline

    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, G, D)
    n_blocks = -(-Skv // block)
    pad = n_blocks * block - Skv
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = kp.reshape(B, n_blocks, block, Hkv, D).astype(jnp.float32)
    vb = vp.reshape(B, n_blocks, block, Hkv, D).astype(jnp.float32)

    q_pos = q_off + jnp.arange(Sq)

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, b_idx = blk
        k_pos = b_idx * block + jnp.arange(block)
        s = jnp.einsum("bshgd,bthd->bhgst", qf, kblk)   # [B,Hkv,G,Sq,block]
        if cap is not None:
            s = softcap(s, cap)
        mask = k_pos[None, :] <= q_pos[:, None] if causal else \
            jnp.ones((Sq, block), bool)
        mask = mask & (k_pos[None, :] < Skv)
        if window is not None:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = _seq_constrain(jnp.maximum(m, s.max(axis=-1)), seq_axes, 3)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = _seq_constrain(l * corr + p.sum(axis=-1), seq_axes, 3)
        acc_new = _seq_constrain(
            acc * corr[..., None] + jnp.einsum("bhgst,bthd->bhgsd", p, vblk),
            seq_axes, 3)
        return (m_new, l_new, acc_new), None

    m0 = _seq_constrain(jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32),
                        seq_axes, 3)
    l0 = _seq_constrain(jnp.zeros((B, Hkv, G, Sq), jnp.float32),
                        seq_axes, 3)
    a0 = _seq_constrain(jnp.zeros((B, Hkv, G, Sq, D), jnp.float32),
                        seq_axes, 3)
    kb_t = jnp.moveaxis(kb, 1, 0)
    vb_t = jnp.moveaxis(vb, 1, 0)
    (m, l, acc), _ = lax.scan(
        body, (m0, l0, a0), (kb_t, vb_t, jnp.arange(n_blocks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out.reshape(B, Hkv * G, Sq, D), 1, 2)  # [B,Sq,H,D]
    return out.astype(q.dtype)


def attention_block(x, params, cfg_layer, positions, cache=None):
    """GQA attention block (pre-norm applied by the caller).

    x: [B, S, D_model].  params: dict(wq, wk, wv, wo [+ q_norm/k_norm]).
    cfg_layer: dict(n_heads, n_kv_heads, head_dim, window, cap, rope_theta,
    causal).

    cache=None (train / prefill): full blockwise-flash attention; returns
      (out, (k, v)) with k/v [B, S, Hkv, Dh] post-RoPE so the serving
      engine can stash them.
    cache=dict(k, v [B,Hkv,C,Dh], len [B]) (decode, S == 1): ring-buffer
      cache of size C (C = window for SWA layers); RoPE uses absolute
      positions so ring order is irrelevant (softmax is permutation
      invariant over KV).  Returns (out, updated cache).
    """
    B, S, _ = x.shape
    H = cfg_layer["n_heads"]
    Hkv = cfg_layer["n_kv_heads"]
    Dh = cfg_layer["head_dim"]
    window = cfg_layer.get("window")
    cap = cfg_layer.get("cap")
    theta = cfg_layer.get("rope_theta", 10_000.0)
    causal = cfg_layer.get("causal", True)

    q = (x @ params["wq"]).reshape(B, S, H, Dh)
    k = (x @ params["wk"]).reshape(B, S, Hkv, Dh)
    v = (x @ params["wv"]).reshape(B, S, Hkv, Dh)
    if "q_norm" in params:     # gemma3-style qk-norm
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if theta is not None:
        cos, sin = rope_angles(positions, Dh, theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    if cache is None:
        if cfg_layer.get("seq_shard") and cfg_layer.get("tp_axis"):
            # context-parallel attention core: shard the SEQUENCE over the
            # tp axis (kv heads < tp size would otherwise pad heads and
            # all-reduce the giant score tensors)
            from jax.sharding import PartitionSpec as PS
            dp = cfg_layer.get("dp_axes") or ()
            dp_e = (tuple(dp) if len(dp) > 1 else dp[0]) if dp else None
            tp = cfg_layer["tp_axis"]
            try:
                q = jax.lax.with_sharding_constraint(
                    q, PS(dp_e, tp, None, None))
                k = jax.lax.with_sharding_constraint(
                    k, PS(dp_e, None, None, None))
                v = jax.lax.with_sharding_constraint(
                    v, PS(dp_e, None, None, None))
            except Exception:
                pass
        seq_axes = None
        if cfg_layer.get("seq_shard") and cfg_layer.get("tp_axis"):
            seq_axes = (tuple(cfg_layer.get("dp_axes") or ()),
                        cfg_layer["tp_axis"])
        out = flash_attention(q, k, v, causal=causal, window=window,
                              cap=cap, seq_axes=seq_axes)
        out = out.reshape(B, S, H * Dh)
        if cfg_layer.get("seq_shard") and cfg_layer.get("tp_axis"):
            from jax.sharding import PartitionSpec as PS
            dp = cfg_layer.get("dp_axes") or ()
            dp_e = (tuple(dp) if len(dp) > 1 else dp[0]) if dp else None
            try:
                out = jax.lax.with_sharding_constraint(
                    out, PS(dp_e, None, cfg_layer["tp_axis"]))
            except Exception:
                pass
        return out @ params["wo"], (k, v)

    assert S == 1, "decode path handles one token at a time"
    ck, cv, clen = cache["k"], cache["v"], cache["len"]
    C = ck.shape[2]
    slot = clen % C                                   # ring position [B]
    k_t = jnp.swapaxes(k, 1, 2)                       # [B, Hkv, 1, Dh]
    v_t = jnp.swapaxes(v, 1, 2)
    ck = jax.vmap(lambda c, u, i: lax.dynamic_update_slice(c, u, (0, i, 0))
                  )(ck, k_t.astype(ck.dtype), slot)
    cv = jax.vmap(lambda c, u, i: lax.dynamic_update_slice(c, u, (0, i, 0))
                  )(cv, v_t.astype(cv.dtype), slot)
    new_len = clen + 1
    eff_len = jnp.minimum(new_len, C)
    qg = q.reshape(B, Hkv, H // Hkv, Dh)
    out = decode_attention(qg, ck, cv, eff_len, cap=cap)
    out = out.reshape(B, S, H * Dh)
    return out @ params["wo"], dict(k=ck, v=cv, len=new_len)
