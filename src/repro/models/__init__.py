from .model import (decode_step, forward, init_cache, init_params, loss_fn,
                    param_count, param_shapes, prefill)

__all__ = ["decode_step", "forward", "init_cache", "init_params", "loss_fn",
           "param_count", "param_shapes", "prefill"]
