"""FSDP + tensor-parallel sharding rules (DESIGN.md §6.1).

Mesh convention: the LAST mesh axis is the tensor-parallel axis (named
"model" everywhere in this repo); every other axis carries the batch
("data", or ("pod", "data") multi-pod).  Rules are name-based over the
``repro.models.model.param_shapes`` tree and divisibility-safe: an axis
is only assigned to a tensor dimension it divides (``sanitize_spec``),
so the same code covers every arch in ``repro.configs`` — including
``scan_layers=True`` stacked shapes, whose leading layer-unit dimension
is never sharded (``lax.scan`` iterates over it).

TP assignment mirrors the Megatron column/row split: output-feature
dims shard over "model" for up-projections (wq/wk/wv/w_gate/w_up/...),
the contraction dim shards for down-projections (wo/w_down/out_proj) so
the following all-reduce is the only collective in the layer; the
embedding shards its vocab dim.  FSDP then shards one remaining dim of
every weight over the data axes (ZeRO-3 style parameter sharding).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["data_axes", "batch_spec", "sanitize_spec", "param_specs",
           "shard_params", "cache_specs"]

# weights whose dim -2 (the contraction dim of the following matmul, or
# the vocab dim of the embedding) carries the tensor-parallel axis; every
# other >=2-D weight shards its LAST dim.
_ROW_SHARDED = frozenset({"wo", "w_down", "sh_down", "out_proj", "embed"})


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """All mesh axes except the (last, tensor-parallel) one."""
    return tuple(mesh.axis_names[:-1])


def _axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_spec(mesh: Mesh) -> P:
    """Batch arrays shard dim 0 over the data axes, replicate the rest."""
    axes = data_axes(mesh)
    if not axes:
        return P()
    return P(axes if len(axes) > 1 else axes[0])


def sanitize_spec(shape, spec: P, mesh: Mesh) -> P:
    """Drop mesh axes from ``spec`` that do not divide their dim.

    Keeps, per dimension, the longest prefix of the assigned axes whose
    cumulative size divides the dim — the spec that comes out is always
    valid to materialise on ``mesh``.
    """
    sizes = _axis_sizes(mesh)
    out = []
    for dim, entry in enumerate(spec):
        if entry is None or dim >= len(shape):
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept, prod = [], 1
        for a in axes:
            if a not in sizes or shape[dim] % (prod * sizes[a]) != 0:
                break
            kept.append(a)
            prod *= sizes[a]
        out.append(tuple(kept) if len(kept) > 1
                   else (kept[0] if kept else None))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def _leaf_shape(leaf) -> Tuple[int, ...]:
    if isinstance(leaf, tuple):
        return tuple(int(d) for d in leaf)
    return tuple(int(d) for d in leaf.shape)


def _is_shape(x) -> bool:
    return (isinstance(x, tuple)
            and all(isinstance(i, (int, np.integer)) for i in x))


def _spec_for(name: str, shape, stacked: bool, mesh: Mesh,
              fsdp: bool) -> P:
    """Spec for one weight.  ``stacked``: leading dim is the scan-unit
    dim (never sharded)."""
    sizes = _axis_sizes(mesh)
    model = mesh.axis_names[-1]
    dp = data_axes(mesh)
    dp_size = int(np.prod([sizes[a] for a in dp])) if dp else 1

    off = 1 if stacked else 0
    eff = shape[off:]
    entries: list = [None] * len(shape)
    if len(eff) >= 2:
        model_dim = (len(shape) - 2 if name in _ROW_SHARDED
                     else len(shape) - 1)
        if shape[model_dim] % sizes[model] == 0:
            entries[model_dim] = model
        else:
            model_dim = -1                       # nothing carries TP
        if fsdp and dp:
            # prefer the dim opposite the TP dim, then any remaining one
            pref = ([len(shape) - 2] if model_dim == len(shape) - 1
                    else [len(shape) - 1])
            pref += [d for d in range(off, len(shape))
                     if d not in pref and d != model_dim]
            for d in pref:
                if entries[d] is None and shape[d] % dp_size == 0:
                    entries[d] = dp if len(dp) > 1 else dp[0]
                    break
    while entries and entries[-1] is None:
        entries.pop()
    return sanitize_spec(shape, P(*entries), mesh)


def param_specs(params_or_shapes, mesh: Mesh, fsdp: bool = False):
    """PartitionSpec tree matching ``param_shapes(cfg)`` (or an actual
    params tree — leaves may be shape tuples or arrays)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params_or_shapes, is_leaf=_is_shape)
    specs = []
    for path, leaf in flat:
        names = [str(getattr(k, "key", getattr(k, "idx", k)))
                 for k in path]
        name = names[-1] if names else ""
        stacked = "layers_stack" in names
        specs.append(_spec_for(name, _leaf_shape(leaf), stacked, mesh,
                               fsdp))
    return jax.tree_util.tree_unflatten(treedef, specs)


def shard_params(params, mesh: Mesh, fsdp: bool = True):
    """device_put every leaf with its ``param_specs`` sharding (global
    arrays — works from single-host replicated inputs)."""
    specs = param_specs(params, mesh, fsdp=fsdp)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        params, specs)


def cache_specs(mesh: Mesh, cache_tree, seq_shard_kv: bool = False):
    """Decode-cache layout: batch over data axes everywhere; KV tensors
    [B, Hkv, S, Dh] shard heads over "model" (or the sequence dim when
    ``seq_shard_kv`` — the right layout when Hkv < tp size); recurrent
    SSM/xLSTM states shard their head dim when it divides."""
    model = mesh.axis_names[-1]
    dp = data_axes(mesh)
    b_entry = (dp if len(dp) > 1 else dp[0]) if dp else None

    def spec(path, leaf):
        shape = _leaf_shape(leaf)
        names = [str(getattr(k, "key", getattr(k, "idx", k)))
                 for k in path]
        name = names[-1] if names else ""
        entries: list = [None] * len(shape)
        if shape:
            entries[0] = b_entry
        if "cross_kv" in names and len(shape) == 4:
            # whisper cross-attention KV [B, F, Hkv, Dh]: heads on dim 2
            # (frames on dim 1 only under context parallelism)
            entries[1 if seq_shard_kv else 2] = model
        elif name in ("k", "v") and len(shape) == 4:
            # ring caches [B, Hkv, S, Dh]: heads on dim 1 (or the
            # sequence dim when Hkv doesn't divide the tp size)
            entries[2 if seq_shard_kv else 1] = model
        elif len(shape) >= 2:
            # recurrent states [B, H, ...]: heads over model
            entries[1] = model
        return sanitize_spec(shape, P(*entries), mesh)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])
