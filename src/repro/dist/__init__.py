"""Distributed substrate (DESIGN.md §6).

Three layers, each usable on its own:

- sharding:       FSDP+TP ``PartitionSpec`` assignment for every model
                  arch in ``repro.configs`` on a ``(*data, "model")``
                  mesh, plus batch / decode-cache layouts;
- collectives:    hand-rolled ring collectives (``jax.lax.ppermute``)
                  whose HLO overlaps compute with communication —
                  ``collective_matmul_ag`` lowers to a
                  ``while{dot, collective-permute}`` loop instead of
                  ``{all-gather, dot}``;
- topology_aware: an alpha-beta-with-hops cost model (``FabricModel``)
                  that scores ring vs direct collective algorithms on
                  any ``repro.core`` topology — the bridge between the
                  paper's fabric analysis and the training stack.
"""

from .collectives import (collective_matmul_ag, ring_all_gather,
                          ring_all_reduce, ring_reduce_scatter)
from .sharding import (batch_spec, cache_specs, data_axes, param_specs,
                       sanitize_spec, shard_params)
from .topology_aware import CollectiveEstimate, FabricModel

__all__ = [
    "batch_spec",
    "cache_specs",
    "data_axes",
    "param_specs",
    "sanitize_spec",
    "shard_params",
    "collective_matmul_ag",
    "ring_all_gather",
    "ring_all_reduce",
    "ring_reduce_scatter",
    "CollectiveEstimate",
    "FabricModel",
]
