"""Overlapped ring collectives built on ``jax.lax.ppermute``
(DESIGN.md §6.2).

Written to be called INSIDE ``jax.shard_map``: every function takes the
local shard plus a mesh-axis name.  The ring loops are ``lax.fori_loop``
over the axis size, so XLA lowers them to a single
``while{dot / add, collective-permute, dynamic-update-slice}`` body —
communication for ring step i+1 overlaps the compute of step i, and no
standalone ``all-gather`` op appears in the HLO (asserted by
``tests/test_distributed.py::test_collective_matmul_overlap_hlo``).

This is the device-level realisation of the two collective algorithms
``repro.dist.topology_aware.FabricModel`` scores analytically: the ring
schedule here is the "ring" algorithm; XLA's native one-shot
``all-reduce`` is the "direct" one.

`emit_policy` (DESIGN.md §13) is the third lowering target: it turns
the same collective algorithms into EXPLICIT-PATH
`repro.sim.workloads.policy.Policy` schedules over any
`repro.core.routing.RoutingTables` topology — per-transfer router
sequences (MIN by default, alternate path sets pluggable), optional
chunking for pipelining, and a wired-in channel-dependency deadlock
check — which the flit engine executes in source-routed mode and
`repro.sim.workloads.search` optimises over.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

__all__ = ["ring_all_reduce", "ring_reduce_scatter", "ring_all_gather",
           "collective_matmul_ag", "emit_policy", "POLICY_KINDS",
           "PATH_SETS"]


def _ring_perm(n: int):
    """Send to the next-higher device id (mod n)."""
    return [(j, (j + 1) % n) for j in range(n)]


def ring_all_reduce(x: jax.Array, axis: str) -> jax.Array:
    """Sum ``x`` over ``axis`` via reduce-scatter + all-gather rings.

    2(n-1) ppermute steps of |x|/n bytes each — the bandwidth-optimal
    schedule.  Payloads that don't divide the axis size are zero-padded
    internally; the result has ``x``'s shape on every device.
    """
    n = lax.psum(1, axis)
    if n == 1:
        return x
    idx = lax.axis_index(axis)
    perm = _ring_perm(n)

    flat = x.reshape(-1)
    size = flat.shape[0]
    pad = (-size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    buf = flat.reshape(n, -1)                    # chunk c = buf[c]

    # --- reduce-scatter: after step i, chunk (idx - i - 1) holds the
    # partial sum of devices {idx - i - 1, ..., idx}.
    def rs_body(i, buf):
        send = lax.dynamic_slice_in_dim(buf, (idx - i) % n, 1, 0)
        recv = lax.ppermute(send, axis, perm)
        k = (idx - 1 - i) % n
        cur = lax.dynamic_slice_in_dim(buf, k, 1, 0)
        return lax.dynamic_update_slice_in_dim(buf, cur + recv, k, 0)

    buf = lax.fori_loop(0, n - 1, rs_body, buf, unroll=False)

    # --- all-gather: chunk (idx + 1) % n is complete; circulate the
    # completed chunks around the same ring.
    def ag_body(i, buf):
        send = lax.dynamic_slice_in_dim(buf, (idx + 1 - i) % n, 1, 0)
        recv = lax.ppermute(send, axis, perm)
        return lax.dynamic_update_slice_in_dim(buf, recv, (idx - i) % n, 0)

    buf = lax.fori_loop(0, n - 1, ag_body, buf, unroll=False)

    out = buf.reshape(-1)
    if pad:
        out = out[:size]
    return out.reshape(x.shape)


def ring_reduce_scatter(x: jax.Array, axis: str) -> jax.Array:
    """Sum over ``axis``, returning this device's 1/n slice of dim 0
    (device d gets chunk d — index-aligned with ``ring_all_gather``)."""
    n = lax.psum(1, axis)
    if n == 1:
        return x
    idx = lax.axis_index(axis)
    perm = _ring_perm(n)
    assert x.shape[0] % n == 0, (x.shape, n)
    buf = x.reshape((n, x.shape[0] // n) + x.shape[1:])

    # after step i, chunk (idx - 2 - i) holds the partial sum of
    # devices {idx - i - 1, ..., idx}; after n-1 steps chunk idx is
    # complete on device idx.
    def rs_body(i, buf):
        send = lax.dynamic_slice_in_dim(buf, (idx - 1 - i) % n, 1, 0)
        recv = lax.ppermute(send, axis, perm)
        k = (idx - 2 - i) % n
        cur = lax.dynamic_slice_in_dim(buf, k, 1, 0)
        return lax.dynamic_update_slice_in_dim(buf, cur + recv, k, 0)

    buf = lax.fori_loop(0, n - 1, rs_body, buf, unroll=False)
    own = lax.dynamic_slice_in_dim(buf, idx, 1, 0)
    return own[0]


def ring_all_gather(x: jax.Array, axis: str) -> jax.Array:
    """Concatenate every device's ``x`` along a new leading ring order
    (device d's shard lands at index d), via n-1 ppermute steps."""
    n = lax.psum(1, axis)
    idx = lax.axis_index(axis)
    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = lax.dynamic_update_slice_in_dim(out, x[None], idx, 0)
    if n == 1:
        return out
    perm = _ring_perm(n)

    def body(i, carry):
        out, cur = carry
        cur = lax.ppermute(cur, axis, perm)
        src = (idx - 1 - i) % n
        out = lax.dynamic_update_slice_in_dim(out, cur[None], src, 0)
        return out, cur

    out, _ = lax.fori_loop(0, n - 1, body, (out, x), unroll=False)
    return out


def collective_matmul_ag(xs: jax.Array, ws: jax.Array,
                         axis: str) -> jax.Array:
    """``all_gather(xs, axis) @ ws`` as an overlapped ring matmul.

    ``xs``: this device's [rows/n, K] shard of the activations;
    ``ws``: [K, N] weights (replicated or row-sharded upstream).
    Each ring step multiplies the shard currently held against ``ws``
    and writes the [rows/n, N] block into its global row position while
    the shard moves to the ring neighbour — the collective-permute for
    step i+1 overlaps the dot of step i (Wang et al., "Overlap
    communication with dependent computation via decomposition", the
    pattern XLA's native all-gather-matmul pass targets).
    """
    n = lax.psum(1, axis)
    idx = lax.axis_index(axis)
    block = xs.shape[0]
    out = jnp.zeros((n * block, ws.shape[-1]),
                    jnp.promote_types(xs.dtype, ws.dtype))
    if n == 1:
        return lax.dynamic_update_slice_in_dim(out, xs @ ws, 0, 0)
    perm = _ring_perm(n)

    def body(i, carry):
        out, cur = carry
        src = (idx - i) % n          # owner of the shard currently held
        out = lax.dynamic_update_slice_in_dim(out, cur @ ws, src * block,
                                              0)
        cur = lax.ppermute(cur, axis, perm)
        return out, cur

    # n-1 permutes suffice: the last shard's dot happens after the loop
    # (permuting it onward would send a full shard nobody reads)
    out, cur = lax.fori_loop(0, n - 1, body, (out, xs), unroll=False)
    last = (idx - (n - 1)) % n
    return lax.dynamic_update_slice_in_dim(out, cur @ ws, last * block, 0)


# ---------------------------------------------------------------------------
# explicit-path policy emission (DESIGN.md §13)
# ---------------------------------------------------------------------------

# collective kind -> (ir builder name, name of its per-message flit arg)
POLICY_KINDS = {
    "ring_all_reduce": ("ring_all_reduce", "chunk_flits"),
    "ring_reduce_scatter": ("ring_reduce_scatter", "chunk_flits"),
    "ring_all_gather": ("ring_all_gather", "chunk_flits"),
    "recdbl_all_reduce": ("recdbl_all_reduce", "size_flits"),
    "all_to_all": ("all_to_all", "flits_per_pair"),
}

PATH_SETS = ("min", "diverse")


def _pick_path(rt, s: int, d: int, path_set, rng) -> list:
    """One concrete router sequence s..d from the configured path set."""
    if callable(path_set):
        return list(path_set(s, d, rng))
    if path_set == "min":
        return rt.min_path(s, d)
    if path_set == "diverse":
        # spread chunks across ALL equal-cost minimal paths (the
        # diameter-2 diversity §II promises and MIN tables never use)
        opts = rt.min_paths_all(s, d)
        if not opts:
            raise ValueError(f"no route {s} -> {d} on these tables")
        return opts[int(rng.integers(len(opts)))]
    raise ValueError(f"unknown path_set {path_set!r}; have {PATH_SETS} "
                     f"or a callable (s, d, rng) -> path")


def _topo_shuffle(entries: list, rng) -> list:
    """Seeded topological reshuffle of a policy entry list (Kahn with
    random ready-pick), dep ids remapped.  Entry ORDER is engine-visible
    — each endpoint injects its first-listed sendable entry — so this
    is the entry-ordering dimension of the schedule search."""
    n = len(entries)
    succ = [[] for _ in range(n)]
    indeg = np.zeros(n, dtype=np.int64)
    for i, e in enumerate(entries):
        indeg[i] = len(e.deps)
        for d in e.deps:
            succ[d].append(i)
    ready = list(np.nonzero(indeg == 0)[0])
    new_of = np.full(n, -1, dtype=np.int64)
    order = []
    while ready:
        i = ready.pop(int(rng.integers(len(ready))))
        new_of[i] = len(order)
        order.append(i)
        for j in succ[i]:
            indeg[j] -= 1
            if indeg[j] == 0:
                ready.append(j)
    assert len(order) == n, "cyclic policy deps"
    import dataclasses as _dc
    return [_dc.replace(entries[i],
                        deps=tuple(sorted(int(new_of[d])
                                          for d in entries[i].deps)))
            for i in order]


def emit_policy(kind: str, rt, n_ranks: int, size_flits: int,
                router_of_rank, n_chunks: int = 1,
                path_set="min", path_seed: int = 0,
                order_seed: Optional[int] = None,
                vcs: int = 4, vc_class: int = 0,
                check_deadlock: bool = True):
    """Lower a collective algorithm to an explicit-path Policy.

    kind           : one of POLICY_KINDS (the message-DAG builders of
                     `repro.sim.workloads.ir`).
    rt             : `repro.core.routing.RoutingTables` of the target
                     topology (healthy or failure-masked — paths only
                     use live links).
    size_flits     : the builder's per-message flit count (ring chunk /
                     full vector / per-pair payload).
    router_of_rank : [n_ranks] router housing each rank (from the
                     placement: ``tables.ep_router[ep_of_rank]``).
    n_chunks       : split every message into up to n_chunks pipelined
                     chunks; chunk c of a message depends on chunk c of
                     each DAG predecessor, so successive chunks overlap
                     the dependency chain.
    path_set       : "min" (deterministic table-MIN routes — the
                     source-vs-table equivalence baseline), "diverse"
                     (seeded spread over all equal-cost minimal paths),
                     or a callable ``(src_router, dst_router, rng) ->
                     path`` for arbitrary path sets (e.g. Valiant).
    order_seed     : when given, topologically reshuffle the entry list
                     (the injection-order dimension of schedule search).
    vcs / vc_class : VC budget and the policy's base VC class; hop h of
                     an entry rides VC ``min(vc_class + h, vcs - 1)``,
                     and `check_deadlock` proves the whole path set
                     acyclic under exactly that clamped assignment
                     (PolicyDeadlockError otherwise).
    """
    # deferred import: repro.sim.workloads.__init__ imports report,
    # which imports repro.dist.topology_aware — importing policy at
    # module scope would close that cycle
    from ..sim.workloads.ir import make_workload
    from ..sim.workloads.policy import Policy, PolicyEntry

    if kind not in POLICY_KINDS:
        raise ValueError(f"unknown collective {kind!r}; "
                         f"have {sorted(POLICY_KINDS)}")
    builder, flit_arg = POLICY_KINDS[kind]
    wl = make_workload(builder, n_ranks=n_ranks,
                       **{flit_arg: size_flits})

    ror = np.asarray(router_of_rank, dtype=np.int64)
    assert ror.shape == (n_ranks,)
    rng = np.random.default_rng(path_seed)
    M = wl.n_messages
    nc = np.minimum(max(1, n_chunks), wl.size).astype(np.int64)  # [M]
    off = np.zeros(M + 1, dtype=np.int64)
    off[1:] = np.cumsum(nc)

    entries = []
    for m in range(M):
        s_r, d_r = int(ror[wl.src[m]]), int(ror[wl.dst[m]])
        base, rem = divmod(int(wl.size[m]), int(nc[m]))
        for c in range(int(nc[m])):
            deps = tuple(int(off[d] + min(c, nc[d] - 1))
                         for d in wl.deps[m])
            entries.append(PolicyEntry(
                chunk_id=m * int(max(1, n_chunks)) + c,
                src_rank=int(wl.src[m]), dst_rank=int(wl.dst[m]),
                vc_class=vc_class,
                size_flits=base + (1 if c < rem else 0),
                path=tuple(_pick_path(rt, s_r, d_r, path_set, rng)),
                deps=deps, phase=int(wl.phase[m])))
    if order_seed is not None:
        entries = _topo_shuffle(entries, np.random.default_rng(order_seed))

    label = path_set if isinstance(path_set, str) else "custom"
    pol = Policy(
        name=f"{wl.name}/nc{max(1, n_chunks)}-{label}", n_ranks=n_ranks,
        router_of_rank=ror, entries=entries, phase_names=wl.phase_names)
    pol.validate(adj=rt.adj)
    if check_deadlock:
        pol.check_deadlock_free(rt.topo.n_routers, vcs)
    return pol
