"""Overlapped ring collectives built on ``jax.lax.ppermute``
(DESIGN.md §6.2).

Written to be called INSIDE ``jax.shard_map``: every function takes the
local shard plus a mesh-axis name.  The ring loops are ``lax.fori_loop``
over the axis size, so XLA lowers them to a single
``while{dot / add, collective-permute, dynamic-update-slice}`` body —
communication for ring step i+1 overlaps the compute of step i, and no
standalone ``all-gather`` op appears in the HLO (asserted by
``tests/test_distributed.py::test_collective_matmul_overlap_hlo``).

This is the device-level realisation of the two collective algorithms
``repro.dist.topology_aware.FabricModel`` scores analytically: the ring
schedule here is the "ring" algorithm; XLA's native one-shot
``all-reduce`` is the "direct" one.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["ring_all_reduce", "ring_reduce_scatter", "ring_all_gather",
           "collective_matmul_ag"]


def _ring_perm(n: int):
    """Send to the next-higher device id (mod n)."""
    return [(j, (j + 1) % n) for j in range(n)]


def ring_all_reduce(x: jax.Array, axis: str) -> jax.Array:
    """Sum ``x`` over ``axis`` via reduce-scatter + all-gather rings.

    2(n-1) ppermute steps of |x|/n bytes each — the bandwidth-optimal
    schedule.  Payloads that don't divide the axis size are zero-padded
    internally; the result has ``x``'s shape on every device.
    """
    n = lax.psum(1, axis)
    if n == 1:
        return x
    idx = lax.axis_index(axis)
    perm = _ring_perm(n)

    flat = x.reshape(-1)
    size = flat.shape[0]
    pad = (-size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    buf = flat.reshape(n, -1)                    # chunk c = buf[c]

    # --- reduce-scatter: after step i, chunk (idx - i - 1) holds the
    # partial sum of devices {idx - i - 1, ..., idx}.
    def rs_body(i, buf):
        send = lax.dynamic_slice_in_dim(buf, (idx - i) % n, 1, 0)
        recv = lax.ppermute(send, axis, perm)
        k = (idx - 1 - i) % n
        cur = lax.dynamic_slice_in_dim(buf, k, 1, 0)
        return lax.dynamic_update_slice_in_dim(buf, cur + recv, k, 0)

    buf = lax.fori_loop(0, n - 1, rs_body, buf, unroll=False)

    # --- all-gather: chunk (idx + 1) % n is complete; circulate the
    # completed chunks around the same ring.
    def ag_body(i, buf):
        send = lax.dynamic_slice_in_dim(buf, (idx + 1 - i) % n, 1, 0)
        recv = lax.ppermute(send, axis, perm)
        return lax.dynamic_update_slice_in_dim(buf, recv, (idx - i) % n, 0)

    buf = lax.fori_loop(0, n - 1, ag_body, buf, unroll=False)

    out = buf.reshape(-1)
    if pad:
        out = out[:size]
    return out.reshape(x.shape)


def ring_reduce_scatter(x: jax.Array, axis: str) -> jax.Array:
    """Sum over ``axis``, returning this device's 1/n slice of dim 0
    (device d gets chunk d — index-aligned with ``ring_all_gather``)."""
    n = lax.psum(1, axis)
    if n == 1:
        return x
    idx = lax.axis_index(axis)
    perm = _ring_perm(n)
    assert x.shape[0] % n == 0, (x.shape, n)
    buf = x.reshape((n, x.shape[0] // n) + x.shape[1:])

    # after step i, chunk (idx - 2 - i) holds the partial sum of
    # devices {idx - i - 1, ..., idx}; after n-1 steps chunk idx is
    # complete on device idx.
    def rs_body(i, buf):
        send = lax.dynamic_slice_in_dim(buf, (idx - 1 - i) % n, 1, 0)
        recv = lax.ppermute(send, axis, perm)
        k = (idx - 2 - i) % n
        cur = lax.dynamic_slice_in_dim(buf, k, 1, 0)
        return lax.dynamic_update_slice_in_dim(buf, cur + recv, k, 0)

    buf = lax.fori_loop(0, n - 1, rs_body, buf, unroll=False)
    own = lax.dynamic_slice_in_dim(buf, idx, 1, 0)
    return own[0]


def ring_all_gather(x: jax.Array, axis: str) -> jax.Array:
    """Concatenate every device's ``x`` along a new leading ring order
    (device d's shard lands at index d), via n-1 ppermute steps."""
    n = lax.psum(1, axis)
    idx = lax.axis_index(axis)
    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = lax.dynamic_update_slice_in_dim(out, x[None], idx, 0)
    if n == 1:
        return out
    perm = _ring_perm(n)

    def body(i, carry):
        out, cur = carry
        cur = lax.ppermute(cur, axis, perm)
        src = (idx - 1 - i) % n
        out = lax.dynamic_update_slice_in_dim(out, cur[None], src, 0)
        return out, cur

    out, _ = lax.fori_loop(0, n - 1, body, (out, x), unroll=False)
    return out


def collective_matmul_ag(xs: jax.Array, ws: jax.Array,
                         axis: str) -> jax.Array:
    """``all_gather(xs, axis) @ ws`` as an overlapped ring matmul.

    ``xs``: this device's [rows/n, K] shard of the activations;
    ``ws``: [K, N] weights (replicated or row-sharded upstream).
    Each ring step multiplies the shard currently held against ``ws``
    and writes the [rows/n, N] block into its global row position while
    the shard moves to the ring neighbour — the collective-permute for
    step i+1 overlaps the dot of step i (Wang et al., "Overlap
    communication with dependent computation via decomposition", the
    pattern XLA's native all-gather-matmul pass targets).
    """
    n = lax.psum(1, axis)
    idx = lax.axis_index(axis)
    block = xs.shape[0]
    out = jnp.zeros((n * block, ws.shape[-1]),
                    jnp.promote_types(xs.dtype, ws.dtype))
    if n == 1:
        return lax.dynamic_update_slice_in_dim(out, xs @ ws, 0, 0)
    perm = _ring_perm(n)

    def body(i, carry):
        out, cur = carry
        src = (idx - i) % n          # owner of the shard currently held
        out = lax.dynamic_update_slice_in_dim(out, cur @ ws, src * block,
                                              0)
        cur = lax.ppermute(cur, axis, perm)
        return out, cur

    # n-1 permutes suffice: the last shard's dot happens after the loop
    # (permuting it onward would send a full shard nobody reads)
    out, cur = lax.fori_loop(0, n - 1, body, (out, xs), unroll=False)
    last = (idx - (n - 1)) % n
    return lax.dynamic_update_slice_in_dim(out, cur @ ws, last * block, 0)
