"""Topology-aware collective cost model (DESIGN.md §6.3).

``FabricModel`` wraps any ``repro.core`` :class:`Topology` and scores
collective algorithms with an alpha-beta model extended with per-pair
HOP DISTANCES and a bisection congestion term — the quantities the Slim
Fly paper optimises (§III).  This is how the paper's contribution (low
diameter, high bisection) shows up as wall-clock for ML workloads: the
latency term of every collective is multiplied by the hop count of the
messages it sends, and the bandwidth term is clamped by the fabric's
bisection.

Two algorithm families per collective (cf. Blach et al.,
arXiv:2310.03742 §VII, who measure exactly this crossover on Slim Fly
hardware):

- ring:   bandwidth-optimal; 2(k-1) (all-reduce) or k-1 (gather /
          scatter / a2a) neighbour steps of payload/k bytes.  Pays the
          per-step software alpha and the ring-neighbour hop latency
          2(k-1) times — expensive on high-diameter fabrics, cheap in
          bytes.
- direct: latency-optimal one-shot exchange; every participant sends to
          every other in one round (all-gather the full payload + local
          reduction for all-reduce).  Pays alpha + hops once, but
          (k-1) x the bytes per NIC plus a bisection congestion factor.

Low-diameter Slim Fly pulls the ring/direct crossover toward much
larger payloads than a fat tree — which is what
``benchmarks/topology_collectives.py`` tabulates.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional

import numpy as np

from ..core.topology import Topology, apply_link_failures

__all__ = ["FabricModel", "CollectiveEstimate"]


@dataclasses.dataclass(frozen=True)
class CollectiveEstimate:
    """One (collective, algorithm, participant-set, payload) estimate."""
    collective: str
    algorithm: str                  # "ring" | "direct"
    time_s: float
    latency_s: float                # alpha + hop terms
    bandwidth_s: float              # serialization + congestion terms
    steps: int
    mean_hops: float                # hops paid per step of this algorithm


class FabricModel:
    """Collective-time estimator for a router topology.

    Endpoints are numbered like ``repro.sim.tables``: ``p`` per
    endpoint router, sorted by router id.  ``estimate`` understands
    ``all_reduce``, ``reduce_scatter``, ``all_gather`` and
    ``all_to_all``; payload is the per-participant byte count (the full
    gradient for all-reduce, the total send volume for all-to-all).
    """

    def __init__(self, topo: Topology,
                 link_bandwidth: float = 12.5e9,    # B/s (100 Gb/s)
                 link_latency: float = 100e-9,      # per router-router hop
                 alpha: float = 1e-6,               # per-message software
                 failed_edges=None):                # DESIGN.md §8 link mask
        if failed_edges is not None:
            # degrade the fabric consistently with routing/sim: hop
            # distances grow, the edge count (congestion denominator)
            # shrinks, and the bisection is re-partitioned on the
            # masked graph.  A disconnected group yields inf estimates.
            topo = apply_link_failures(topo, failed_edges)
        self.topo = topo
        self.link_bandwidth = float(link_bandwidth)
        self.link_latency = float(link_latency)
        self.alpha = float(alpha)
        if topo.endpoint_mask is None:
            ep_routers = np.arange(topo.n_routers)
        else:
            ep_routers = np.nonzero(topo.endpoint_mask)[0]
        self.ep_router = np.repeat(ep_routers, topo.p)
        self.n_nodes = int(self.ep_router.shape[0])
        self.dist = topo.distance_matrix()
        self._bisection: Optional[int] = None

    # -- fabric quantities --------------------------------------------------
    @property
    def bisection_channels(self) -> int:
        """Router-router channels crossing a balanced bisection (upper
        bound; computed lazily — it runs a spectral partition)."""
        if self._bisection is None:
            from ..core.bisection import bisection_channels
            self._bisection = max(1, bisection_channels(self.topo))
        return self._bisection

    def _hops(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        return self.dist[self.ep_router[a], self.ep_router[b]]

    def mean_pair_hops(self, group: np.ndarray) -> float:
        """Mean hop distance over ordered distinct pairs of the group."""
        r = self.ep_router[group]
        d = self.dist[np.ix_(r, r)]
        k = len(group)
        if k < 2:
            return 0.0
        return float(d.sum() / (k * (k - 1)))

    def ring_hops(self, group: np.ndarray) -> float:
        """Mean hop distance between consecutive ring neighbours (the
        participant order is the ring order, as in NCCL)."""
        if len(group) < 2:
            return 0.0
        nxt = np.roll(group, -1)
        return float(self._hops(group, nxt).mean())

    # -- the model ----------------------------------------------------------
    def _ring(self, collective: str, payload: float,
              group: np.ndarray) -> CollectiveEstimate:
        k = len(group)
        B = self.link_bandwidth
        h = self.ring_hops(group)
        if k < 2:
            return CollectiveEstimate(collective, "ring", 0.0, 0.0, 0.0,
                                      0, 0.0)
        if collective == "all_reduce":
            steps = 2 * (k - 1)
            wire = 2.0 * (k - 1) / k * payload
        elif collective in ("reduce_scatter", "all_gather"):
            steps = k - 1
            wire = (k - 1) / k * payload
        elif collective == "all_to_all":
            steps = k - 1
            wire = (k - 1) / k * payload
        else:
            raise ValueError(collective)
        lat = steps * (self.alpha + h * self.link_latency)
        bw = wire / B
        return CollectiveEstimate(collective, "ring", lat + bw, lat, bw,
                                  steps, h)

    def _direct(self, collective: str, payload: float,
                group: np.ndarray) -> CollectiveEstimate:
        k = len(group)
        B = self.link_bandwidth
        h = self.mean_pair_hops(group)
        if k < 2:
            return CollectiveEstimate(collective, "direct", 0.0, 0.0,
                                      0.0, 0, 0.0)
        if collective == "all_reduce":
            # one-shot: broadcast the full payload to every peer, reduce
            # locally (latency-optimal, bandwidth-greedy)
            rounds, msg = 1, payload
        elif collective in ("reduce_scatter", "all_gather"):
            rounds, msg = 1, payload / k
        elif collective == "all_to_all":
            rounds, msg = 1, payload / k
        else:
            raise ValueError(collective)
        nic = rounds * (k - 1) * msg / B            # NIC serialization
        # congestion: total link traversals vs fabric capacity, and
        # bytes crossing the bisection vs bisection capacity
        total_bytes = rounds * k * (k - 1) * msg
        links = max(1, 2 * self.topo.n_edges)       # directed channels
        t_links = total_bytes * max(h, 1.0) / (links * B)
        t_bis = total_bytes / (4.0 * self.bisection_channels * B)
        lat = rounds * (self.alpha + h * self.link_latency)
        bw = max(nic, t_links, t_bis)
        return CollectiveEstimate(collective, "direct", lat + bw, lat,
                                  bw, rounds, h)

    def estimate(self, collective: str, payload_bytes: float,
                 participants: Iterable[int]
                 ) -> Dict[str, CollectiveEstimate]:
        """Score ring vs direct for one collective; ``best`` picks the
        faster algorithm for this (collective, payload, group)."""
        group = np.asarray(list(participants), dtype=np.int64)
        assert group.size == 0 or (0 <= group).all(), group
        assert (group < self.n_nodes).all(), (group.max(), self.n_nodes)
        ring = self._ring(collective, float(payload_bytes), group)
        direct = self._direct(collective, float(payload_bytes), group)
        best = ring if ring.time_s <= direct.time_s else direct
        return {"ring": ring, "direct": direct, "best": best}
