"""Tiled (min,+)-semiring matmul Pallas TPU kernel.

C[b, i, j] = min_k ( A[b, i, k] + B[b, k, j] )

This is the hot spot of the Slim Fly analysis pipeline: all-pairs shortest
paths by repeated min-plus squaring (diameter, average distance — Fig 1 /
Table II — and the batched link-failure resiliency study §III-D, which
min-plus-squares hundreds of perturbed adjacency matrices).

TPU adaptation (DESIGN.md §3): BFS pointer-chasing is replaced by dense
blocked semiring algebra.  The MXU cannot evaluate a (min,+) contraction,
so the inner loop is a VPU-vectorized rank-1 sweep over the K tile: each
step does a [bm, bn] broadcast-add + min, which maps onto 8x128 VREGs.
Block shapes keep the working set (3 tiles + accumulator) well inside VMEM:
bm = bn = bk = 128  =>  4 * 128*128*4 B = 256 KiB.

Grid: (B, M/bm, N/bn, K/bk), K innermost (sequential revisit of the output
block; the accumulator lives in the output ref, initialised at k == 0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["minplus_pallas", "DEFAULT_BLOCK"]

DEFAULT_BLOCK = 128
_BIG = 3.0e38  # acts as +inf but keeps inf-free arithmetic (python literal
               # so the kernel does not capture a traced constant)


def _minplus_kernel(a_ref, b_ref, o_ref, *, bk: int):
    k_idx = pl.program_id(3)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, _BIG)

    a = a_ref[0]  # [bm, bk]
    b = b_ref[0]  # [bk, bn]

    def body(kk, acc):
        # rank-1 (min,+) update: acc = min(acc, a[:, kk] + b[kk, :])
        col = lax.dynamic_slice_in_dim(a, kk, 1, axis=1)      # [bm, 1]
        row = lax.dynamic_slice_in_dim(b, kk, 1, axis=0)      # [1, bn]
        return jnp.minimum(acc, col + row)

    acc = lax.fori_loop(0, bk, body, o_ref[...][0])
    o_ref[...] = acc[None]


@functools.partial(jax.jit, static_argnames=("block",))
def minplus_pallas(a: jax.Array, b: jax.Array, block: int = DEFAULT_BLOCK):
    """Batched (min,+) matmul.  a: [B, M, K], b: [B, K, N] (or unbatched 2-D).
    float32/bfloat16.  Entries >= 1e38 are treated as +inf by convention."""
    squeeze = a.ndim == 2
    if squeeze:
        a, b = a[None], b[None]
    B, M, K = a.shape
    _, K2, N = b.shape
    assert K == K2 and b.shape[0] == B

    pad = lambda n: (-n) % block
    a = jnp.pad(a, ((0, 0), (0, pad(M)), (0, pad(K))), constant_values=_BIG)
    b = jnp.pad(b, ((0, 0), (0, pad(K)), (0, pad(N))), constant_values=_BIG)
    Mp, Kp, Np = a.shape[1], a.shape[2], b.shape[2]

    grid = (B, Mp // block, Np // block, Kp // block)
    out = pl.pallas_call(
        functools.partial(_minplus_kernel, bk=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block, block), lambda bt, i, j, k: (bt, i, k)),
            pl.BlockSpec((1, block, block), lambda bt, i, j, k: (bt, k, j)),
        ],
        out_specs=pl.BlockSpec((1, block, block), lambda bt, i, j, k: (bt, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, Mp, Np), a.dtype),
        interpret=_interpret_mode(),
    )(a, b)
    out = out[:, :M, :N]
    # saturate accumulated "inf + inf" values back to _BIG
    out = jnp.minimum(out, _BIG)
    return out[0] if squeeze else out


def _interpret_mode() -> bool:
    """Pallas TPU kernels run in interpret mode on CPU-only hosts."""
    return jax.default_backend() != "tpu"
