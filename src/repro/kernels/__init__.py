"""Pallas TPU kernels for the framework's compute hot spots.

- minplus:     tiled (min,+)-semiring matmul - APSP / topology analysis
- attn_decode: GQA flash-decode over long KV caches - serving path
ops.py holds the jit'd public wrappers; ref.py the pure-jnp oracles.
On non-TPU hosts every kernel runs in interpret mode (bit-accurate).
"""

from .ops import INF, apsp, decode_attention, minplus, seed_distance

__all__ = ["INF", "apsp", "decode_attention", "minplus", "seed_distance"]
