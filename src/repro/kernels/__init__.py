"""Pallas TPU kernels for the framework's compute hot spots.

- minplus:     tiled (min,+)-semiring matmul - APSP / topology analysis
- attn_decode: GQA flash-decode over long KV caches - serving path
- alloc:       flit-simulator inner loops - W-round switch allocation
               and UGAL/VAL candidate scoring (DESIGN.md §9)
ops.py holds the jit'd public wrappers; ref.py the pure-jnp oracles.
On non-TPU hosts every kernel runs in interpret mode (bit-accurate).
"""

from .alloc import alloc_rounds, ugal_select
from .ops import INF, apsp, decode_attention, minplus, seed_distance

__all__ = ["INF", "alloc_rounds", "apsp", "decode_attention", "minplus",
           "seed_distance", "ugal_select"]
