"""Pure-jnp oracles for every Pallas kernel in this package.

The switch-allocation and UGAL-scoring oracles are written as
row-independent math helpers (`_alloc_rounds_math`, `_ugal_score_math`)
shared verbatim with the Pallas kernels in `alloc.py`: the kernel runs
the same function on a block of rows, so ref and pallas paths agree
bit-for-bit by construction (asserted end-to-end by
tests/test_engine_scaling.py).

Lane batching (DESIGN.md §10): the oracles are rank-fixed; an extra
leading lane axis is handled by the dispatchers in `alloc.py`, which
jax.vmap whichever implementation is selected.  vmap of a pure-jnp
oracle is value-preserving per lane by construction, and vmap of the
Pallas kernels appends a lane dimension to the grid without renumbering
`program_id`, so the per-lane bit-equality between the two paths is
unchanged (asserted per lane by tests/test_sweep.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "minplus_ref", "apsp_ref", "decode_attention_ref",
    "alloc_rounds_ref", "ugal_select_ref",
]


def minplus_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C[..., i, j] = min_k A[..., i, k] + B[..., k, j] (broadcast batch)."""
    return jnp.min(a[..., :, :, None] + b[..., None, :, :], axis=-2)


def apsp_ref(adj_dist: jax.Array, n_iter: int) -> jax.Array:
    """APSP by repeated (min,+) squaring of the seeded distance matrix."""
    d = adj_dist
    for _ in range(n_iter):
        d = minplus_ref(d, d)
    return d


def decode_attention_ref(q, k, v, scale: float | None = None, length=None,
                         cap: float | None = None):
    """GQA decode attention oracle.

    q: [B, Hkv, G, d]    (one new token; G = query heads per kv head)
    k: [B, Hkv, S, d]
    v: [B, Hkv, S, dv]
    length: optional [B] valid KV length (positions >= length masked out).
    returns [B, Hkv, G, dv]
    """
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(q.dtype)
    scores = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if cap is not None:
        scores = cap * jnp.tanh(scores / cap)
    if length is not None:
        pos = jnp.arange(k.shape[2])
        mask = pos[None, :] < length[:, None]          # [B, S]
        scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------- switch --
# W-round rotating-priority switch allocation (repro.sim.engine, DESIGN.md
# §5/§9).  All arrays are router-major: every row is one router, so the
# math below is row-local and a Pallas grid can partition rows freely.
#
# Priority note: the seed engine ranked channel requests by
# ``rot * R + qidx`` with ``rot = (qidx + cycle*7919 + w*131) % R`` — at
# paper scale (q=17, R = 65314 request queues) that product reaches
# ~4.3e9 and silently wraps int32.  Because qidx -> rot is a bijection
# (a shift mod R), all rot values are distinct and ``argmin(rot)``
# selects the same winner as ``argmin(rot * R + qidx)`` did where the
# latter was well-defined; we therefore rank by ``rot`` alone, which
# stays < R.  The additive term cycle*7919 + qidx + w*131 itself stays
# below int32 for cycle <= 200k (the closed-loop max) and R <= 2^18
# (q=25), asserted in tests/test_engine_scaling.py.


# Requests of a router are indexed 0..K-1 with K = PV + PE (net queues
# then source queues).  Channel arbitration packs (priority, request
# index) into one int32 — KSHIFT must exceed K and R * KSHIFT must stay
# below 2^31; q=25 (R = 208750, K = 167) leaves ~40x headroom.
KSHIFT = 256


def _alloc_rounds_math(cycle, out_n, ej_n, sp_n, cnt_n,
                       out_s, ej_s, sp_s, cnt_s, epr, row0,
                       *, W: int, P: int, V: int, PE: int,
                       p_budget: int, NQ: int, R: int,
                       use_gather: bool = True):
    """W rounds of matching for a block of routers.

    Shapes (B = routers in this block, PV = P*V; the W axis is LAST so
    the engine's [N,P,V,W] desire arrays reshape in without copies):
      out_n/ej_n/sp_n: [B, PV, W] desired out port / eject flag / space
      cnt_n:           [B, PV]    queue depth at cycle start (0 = dead port)
      out_s/ej_s/sp_s: [B, PE, W] the router's endpoint (source) queues
      cnt_s:           [B, PE]
      epr:             [B, 1]     endpoint-block index of the router (-1)
      row0:            scalar     global id of row 0 (Pallas block offset)

    Returns (chan_slot_net [B, PV], ej_slot_net [B, PV],
             chan_slot_src [B, PE], ej_slot_src [B, PE],
             win_req [B, P]): the window offset granted per queue (-1 =
    no grant) split by grant kind, plus the winning request index (into
    the router's K requests; -1 = idle) per output channel — each
    channel carries at most one packet per cycle, so one [B, P] index
    array captures every arrival (the engine turns it into dense
    per-(router, port) gathers instead of a scatter).
    """
    B = cnt_n.shape[0]
    PV = P * V
    K = PV + PE
    assert K < KSHIFT, f"request index overflows KSHIFT lanes: {K}"
    i32 = jnp.int32
    intmax = jnp.iinfo(jnp.int32).max

    col_pv = lax.broadcasted_iota(i32, (B, PV), 1)
    col_pe = lax.broadcasted_iota(i32, (B, PE), 1)
    col_k = lax.broadcasted_iota(i32, (B, K), 1)
    rows = row0 + lax.broadcasted_iota(i32, (B, 1), 0)
    qidx_n = rows * PV + col_pv                      # global queue ids
    qidx_s = NQ + epr * PE + col_pe                  # (junk when epr < 0:
    chan_ids = lax.broadcasted_iota(i32, (B, P, 1), 1)  # masked by cnt==0)

    s_rot = cycle % PV                               # ejection rotation
    net_first = (cycle % 2) == 0
    base = cycle * jnp.int32(7919)

    granted_n = jnp.zeros((B, PV), bool)
    granted_s = jnp.zeros((B, PE), bool)
    chan_taken = jnp.zeros((B, P), bool)
    budget = jnp.full((B, 1), p_budget, i32)
    cs_n = jnp.full((B, PV), -1, i32)
    es_n = jnp.full((B, PV), -1, i32)
    cs_s = jnp.full((B, PE), -1, i32)
    es_s = jnp.full((B, PE), -1, i32)
    win_req = jnp.full((B, P), -1, i32)

    # hoisted across rounds: request -> channel one-hot (out ports are
    # fixed per window slot) and the rotation base priorities
    out_kw = jnp.concatenate([out_n, out_s], axis=1)     # [B, K, W]
    match_all = out_kw[:, None, :, :] == chan_ids[..., None]  # [B,P,K,W]
    qidx_k = jnp.concatenate([qidx_n, qidx_s], axis=1)
    rot0 = (qidx_k + base) % R                           # [B, K]

    for w in range(W):
        vn = (cnt_n > w) & ~granted_n
        vs = (cnt_s > w) & ~granted_s
        ejn = ej_n[:, :, w] != 0
        ejs = ej_s[:, :, w] != 0
        spn = sp_n[:, :, w] != 0
        sps = sp_s[:, :, w] != 0

        # --- ejection grants: rotating rank over the router's net
        # queues (start column rotates with the cycle), endpoints ranked
        # before/after by cycle parity, against the shared budget of p
        # ejection ports.  rank = exclusive prefix count in rotated
        # order, computed in closed form instead of roll+cumsum+roll.
        mn = (vn & ejn).astype(i32)
        ms = (vs & ejs).astype(i32)
        cn = jnp.cumsum(mn, axis=1) - mn             # exclusive prefix
        sn = mn.sum(axis=1, keepdims=True)
        c_at = jnp.sum(jnp.where(col_pv == s_rot, cn, 0), axis=1,
                       keepdims=True)
        rank_n = cn - c_at + jnp.where(col_pv < s_rot, sn, 0)
        cs_pre = jnp.cumsum(ms, axis=1) - ms
        ss = ms.sum(axis=1, keepdims=True)
        rank_nf = rank_n + jnp.where(net_first, 0, ss)
        rank_sf = cs_pre + jnp.where(net_first, sn, 0)
        g_ej_n = (mn > 0) & (rank_nf < budget)
        g_ej_s = (ms > 0) & (rank_sf < budget)
        budget = (budget - g_ej_n.sum(axis=1, keepdims=True)
                  - g_ej_s.sum(axis=1, keepdims=True))

        # --- channel grants: lowest rotating priority among eligible
        # requests per output channel; one winner per channel per cycle.
        # Priorities are distinct (qidx -> rot is a bijection mod R), so
        # packing (rot, request index) into rot*KSHIFT + idx lets one
        # min-reduction produce both the winner's priority and its
        # identity; a channel with any eligible request always grants.
        elig_n = vn & ~ejn & spn
        elig_s = vs & ~ejs & sps
        cmb = ((rot0 + jnp.int32(w * 131)) % R) * KSHIFT + col_k  # [B, K]
        out_all = out_kw[:, :, w]
        elig = jnp.concatenate([elig_n, elig_s], axis=1)
        live = (match_all[..., w]
                & ~chan_taken[:, :, None] & elig[:, None, :])  # [B, P, K]
        cmin = jnp.min(jnp.where(live, cmb[:, None, :], intmax),
                       axis=2)                       # [B, P]
        won = cmin < intmax
        if use_gather:
            # per-request winner test via a [B, K] row gather of the
            # channel minima — cheap on CPU/GPU.  cmb values are
            # distinct across requests, so equality alone identifies
            # the winner (taken/ineligible rows can never match).
            cmin_at = jnp.take_along_axis(cmin, jnp.maximum(out_all, 0),
                                          axis=1)
            win_all = elig & (out_all >= 0) & (cmb == cmin_at)
        else:
            # gather-free form for the TPU kernel (identical winners:
            # cmb values are distinct, so == picks exactly one)
            win_all = (live & (cmb[:, None, :] == cmin[:, :, None])
                       ).any(axis=1)
        win_n, win_s = win_all[:, :PV], win_all[:, PV:]
        chan_taken = chan_taken | won
        win_req = jnp.where(won, cmin % KSHIFT, win_req)

        granted_n = granted_n | win_n | g_ej_n
        granted_s = granted_s | win_s | g_ej_s
        cs_n = jnp.where(win_n, w, cs_n)
        es_n = jnp.where(g_ej_n, w, es_n)
        cs_s = jnp.where(win_s, w, cs_s)
        es_s = jnp.where(g_ej_s, w, es_s)

    return cs_n, es_n, cs_s, es_s, win_req


def alloc_rounds_ref(cycle, out_net, ej_net, space_net, count_net,
                     out_src, ej_src, space_src, count_src, epr_index,
                     *, W: int, P: int, V: int, PE: int, p_budget: int,
                     NQ: int, R: int):
    """Full-array oracle for the W-round allocation kernel."""
    return _alloc_rounds_math(
        jnp.asarray(cycle, jnp.int32), out_net, ej_net, space_net,
        count_net, out_src, ej_src, space_src, count_src,
        epr_index.reshape(-1, 1), jnp.int32(0),
        W=W, P=P, V=V, PE=PE, p_budget=p_budget, NQ=NQ, R=R,
        use_gather=True)


# ------------------------------------------------------------ UGAL score --
def _ugal_score_math(len_min, len_val, occ_min, occ_val,
                     *, ugal_g: bool, unreach: int, big: int):
    """Score MIN vs the C VAL candidates and pick the best (first-min).

    len_min [E, 1] / len_val [E, C]: path lengths (int32, >= unreach =
    dead); occ_min/occ_val: the matching pre-gathered occupancy terms
    (first-hop queue for UGAL-L, whole-path sums for UGAL-G, already
    OCC_CAP-clamped by the engine).  Returns [E, 1] int32 index into
    the [MIN, cand_0, .., cand_{C-1}] row (0 = MIN; ties go to MIN,
    matching argmin-first).
    """
    if ugal_g:
        sm = occ_min + len_min
        sv = occ_val + len_val
    else:
        sm = len_min * occ_min
        sv = len_val * occ_val
    sm = jnp.where(len_min < unreach, sm, big)
    sv = jnp.where(len_val < unreach, sv, big)
    scores = jnp.concatenate([sm, sv], axis=1)       # [E, 1 + C]
    m = jnp.min(scores, axis=1, keepdims=True)
    idx = lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    first = jnp.min(jnp.where(scores == m, idx, scores.shape[1]),
                    axis=1, keepdims=True)
    return first.astype(jnp.int32)


def ugal_select_ref(len_min, len_val, occ_min, occ_val,
                    *, ugal_g: bool, unreach: int, big: int):
    """Full-array oracle for the UGAL candidate-scoring kernel.

    len_min/occ_min: [E]; len_val/occ_val: [E, C].  Returns best [E].
    """
    return _ugal_score_math(
        len_min[:, None], len_val, occ_min[:, None], occ_val,
        ugal_g=ugal_g, unreach=unreach, big=big)[:, 0]
