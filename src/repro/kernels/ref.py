"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["minplus_ref", "apsp_ref", "decode_attention_ref"]


def minplus_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C[..., i, j] = min_k A[..., i, k] + B[..., k, j] (broadcast batch)."""
    return jnp.min(a[..., :, :, None] + b[..., None, :, :], axis=-2)


def apsp_ref(adj_dist: jax.Array, n_iter: int) -> jax.Array:
    """APSP by repeated (min,+) squaring of the seeded distance matrix."""
    d = adj_dist
    for _ in range(n_iter):
        d = minplus_ref(d, d)
    return d


def decode_attention_ref(q, k, v, scale: float | None = None, length=None,
                         cap: float | None = None):
    """GQA decode attention oracle.

    q: [B, Hkv, G, d]    (one new token; G = query heads per kv head)
    k: [B, Hkv, S, d]
    v: [B, Hkv, S, dv]
    length: optional [B] valid KV length (positions >= length masked out).
    returns [B, Hkv, G, dv]
    """
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(q.dtype)
    scores = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if cap is not None:
        scores = cap * jnp.tanh(scores / cap)
    if length is not None:
        pos = jnp.arange(k.shape[2])
        mask = pos[None, :] < length[:, None]          # [B, S]
        scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
