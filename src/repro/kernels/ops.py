"""Jit'd public wrappers around the Pallas kernels.

These are the entry points the rest of the framework uses; they take care
of padding / reshaping so kernel-side shapes stay hardware-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .attn_decode import decode_attention_pallas
from .minplus import DEFAULT_BLOCK, minplus_pallas

__all__ = ["minplus", "apsp", "seed_distance", "decode_attention", "INF"]

INF = 1.0e38  # values >= INF/10 are "unreachable" by convention


def seed_distance(adj: np.ndarray | jax.Array) -> jax.Array:
    """Adjacency (bool, [..., N, N]) -> seeded distance matrix (f32):
    0 on the diagonal, 1 for edges, +BIG elsewhere."""
    adj = jnp.asarray(adj, dtype=bool)
    n = adj.shape[-1]
    eye = jnp.eye(n, dtype=bool)
    d = jnp.where(adj, 1.0, 3.0e38).astype(jnp.float32)
    return jnp.where(eye, 0.0, d)


def minplus(a, b, *, block: int = DEFAULT_BLOCK, use_pallas: bool = True):
    if use_pallas:
        return minplus_pallas(a, b, block=block)
    return ref.minplus_ref(a, b)


def apsp(adj, *, max_diameter: int | None = None, block: int = DEFAULT_BLOCK,
         use_pallas: bool = True):
    """All-pairs shortest path lengths by (min,+) repeated squaring.

    adj: bool adjacency [..., N, N] (batched over leading dims).
    After t squarings the matrix holds all distances <= 2^t, so
    ceil(log2(max_diameter)) iterations suffice; default assumes the worst
    case (N) => ceil(log2(N)) iterations.
    Returns float32 distances with +BIG (>= 1e38) marking unreachable pairs.
    """
    d = seed_distance(adj)
    n = d.shape[-1]
    target = max_diameter if max_diameter is not None else n
    n_iter = max(1, int(np.ceil(np.log2(max(2, target)))))
    for _ in range(n_iter):
        d = minplus(d, d, block=block, use_pallas=use_pallas)
    return d


def decode_attention(q, k, v, length=None, *, bs: int = 512,
                     cap: float | None = None,
                     use_pallas: bool | None = None):
    """GQA decode attention with automatic hardware-alignment padding.

    q: [B, Hkv, G, d]; k, v: [B, Hkv, S, d]; length: [B] valid KV lengths.

    use_pallas=None resolves by backend: the Pallas kernel on TPU, the
    pure-jnp reference elsewhere (a pallas custom-call is opaque to the
    GSPMD partitioner, which would gather sharded KV caches; the jnp path
    partitions cleanly — sequence-sharded decode).  Kernel correctness vs
    the reference is covered by tests with use_pallas=True (interpret).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return ref.decode_attention_ref(q, k, v, length=length, cap=cap)
    B, Hkv, G, d = q.shape
    dv = v.shape[-1]
    scale = float(1.0 / (d**0.5))  # scale by TRUE head dim before padding
    pad_g = (-G) % 8
    pad_d = (-d) % 128
    pad_dv = (-dv) % 128
    if pad_g or pad_d:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_g), (0, pad_d)))
    if pad_d:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, pad_d)))
    if pad_dv:
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad_dv)))
    out = decode_attention_pallas(q, k, v, length, bs=bs, scale=scale,
                                  cap=cap)
    return out[:, :, :G, :dv]
