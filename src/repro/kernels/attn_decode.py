"""GQA flash-decode Pallas TPU kernel (one query token, long KV cache).

This is the perf-critical op of the serving path (decode_32k / long_500k
shapes): a single new token attends over an S-long KV cache.  The op is
memory-bound (arithmetic intensity ~ O(G)), so the kernel's job is to
stream K/V through VMEM exactly once with an online-softmax accumulator.

Layout: q [B, Hkv, G, d], k/v [B, Hkv, S, d]  (G = query heads per kv head,
pre-padded to a multiple of 8 by the ops.py wrapper; d multiple of 128).

Grid: (B, Hkv, S/bs) with the S dimension innermost/sequential; the
running max / sum / accumulator live in VMEM scratch that persists across
the S sweep of one (B, Hkv) block.  Block working set:
  k,v tiles 2 * bs*d*4 B  (bs=512, d=128: 512 KiB) + acc G*d*4 — << VMEM.

The valid KV length per batch row arrives via scalar prefetch (SMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_pallas"]

_NEG_BIG = -3.0e38


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, bs: int, scale: float,
                   cap: float | None):
    b_idx = pl.program_id(0)
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_BIG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)      # [G, d]
    k = k_ref[0, 0].astype(jnp.float32)      # [bs, d]
    v = v_ref[0, 0].astype(jnp.float32)      # [bs, dv]

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale        # [G, bs]
    if cap is not None:                                    # logit softcap
        scores = cap * jnp.tanh(scores / cap)

    # mask out positions beyond the valid cache length
    length = len_ref[b_idx]
    pos = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(pos < length, scores, _NEG_BIG)

    m_prev = m_scr[...]                       # [G, 1]
    m_new = jnp.maximum(m_prev, scores.max(axis=1, keepdims=True))
    p = jnp.exp(scores - m_new)               # [G, bs]
    corr = jnp.exp(m_prev - m_new)            # [G, 1]
    l_new = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)    # [G, dv]
    acc_new = acc_scr[...] * corr + pv

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(s_idx == n_s - 1)
    def _finalize():
        o_ref[...] = (acc_new / l_new).astype(o_ref.dtype)[None, None]


@functools.partial(jax.jit, static_argnames=("bs", "scale", "cap"))
def decode_attention_pallas(q, k, v, length=None, *, bs: int = 512,
                            scale: float | None = None,
                            cap: float | None = None):
    """q: [B, Hkv, G, d]; k, v: [B, Hkv, S, d]; length: [B] or None.
    Caller must pad G to a multiple of 8 and d to a multiple of 128
    (ops.py does this).  Returns [B, Hkv, G, dv]."""
    B, Hkv, G, d = q.shape
    S = k.shape[2]
    dv = v.shape[3]
    if scale is None:
        scale = float(1.0 / (d**0.5))
    if length is None:
        length = jnp.full((B,), S, dtype=jnp.int32)
    length = length.astype(jnp.int32)

    pad_s = (-S) % bs
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
    Sp = S + pad_s

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, Sp // bs),
        in_specs=[
            pl.BlockSpec((1, 1, G, d), lambda b, h, s, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, d), lambda b, h, s, *_: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs, dv), lambda b, h, s, *_: (b, h, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dv), lambda b, h, s, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dv), jnp.float32),
        ],
    )
    kernel = functools.partial(_decode_kernel, bs=bs, scale=scale, cap=cap)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, dv), q.dtype),
        interpret=jax.default_backend() != "tpu",
    )(length, q, k, v)
