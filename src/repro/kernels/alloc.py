"""Pallas TPU kernels for the flit-simulator hot path (DESIGN.md §9).

Two kernels, mirroring the two inner loops that dominate engine runtime:

- ``alloc_rounds``: W rounds of rotating-priority switch allocation
  (ejection ranking + per-output-channel arbitration).  All state is
  router-local once desires/space are pre-gathered (see
  `repro.sim.engine.SwitchCore.alloc`), so the grid partitions routers
  into blocks of ``BN`` rows and each block runs the full W-round loop
  in VMEM.  Working set per block: ~W * (PV + PE) request words plus a
  [BN, P, PV+PE] match mask — ~200 KiB at q=25, comfortably in VMEM.

- ``ugal_select``: VAL/UGAL candidate scoring — score MIN vs C Valiant
  candidates from pre-gathered path lengths and occupancy terms and
  return the per-endpoint winner.  Blocked over endpoints; the C+1
  score lanes are narrow for the VPU, but the kernel fuses the scoring,
  liveness masking and first-min select into one pass over [BE, C+1].

Both kernels call the SAME row-local math helpers as the pure-jnp
oracles in `ref.py` (`_alloc_rounds_math`, `_ugal_score_math`), so the
``ref`` and ``pallas`` engine paths agree bit-for-bit by construction;
tests/test_engine_scaling.py asserts full-`SimResult` equality.  On
non-TPU hosts the kernels run in interpret mode, like `minplus`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

__all__ = ["alloc_rounds", "alloc_rounds_pallas", "ugal_select",
           "ugal_select_pallas", "ALLOC_BLOCK_N", "UGAL_BLOCK_E"]

ALLOC_BLOCK_N = 8            # routers per allocation block
UGAL_BLOCK_E = 512           # endpoints per scoring block


def _interpret_mode() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x, rows, fill=0):
    pad = rows - x.shape[0]
    if pad == 0:
        return x
    cfg = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, cfg, constant_values=fill)


# ------------------------------------------------------------ allocation --
def _alloc_kernel(cycle_ref, out_n_ref, ej_n_ref, sp_n_ref, cnt_n_ref,
                  out_s_ref, ej_s_ref, sp_s_ref, cnt_s_ref, epr_ref,
                  cs_n_ref, es_n_ref, cs_s_ref, es_s_ref, win_req_ref,
                  *, W, P, V, PE, p_budget, NQ, R, BN):
    row0 = pl.program_id(0) * BN
    cs_n, es_n, cs_s, es_s, win_req = ref._alloc_rounds_math(
        cycle_ref[0, 0],
        out_n_ref[...], ej_n_ref[...], sp_n_ref[...], cnt_n_ref[...],
        out_s_ref[...], ej_s_ref[...], sp_s_ref[...], cnt_s_ref[...],
        epr_ref[...], row0,
        W=W, P=P, V=V, PE=PE, p_budget=p_budget, NQ=NQ, R=R,
        use_gather=False)
    cs_n_ref[...] = cs_n
    es_n_ref[...] = es_n
    cs_s_ref[...] = cs_s
    es_s_ref[...] = es_s
    win_req_ref[...] = win_req


@functools.partial(jax.jit, static_argnames=(
    "W", "P", "V", "PE", "p_budget", "NQ", "R", "block"))
def alloc_rounds_pallas(cycle, out_net, ej_net, space_net, count_net,
                        out_src, ej_src, space_src, count_src, epr_index,
                        *, W: int, P: int, V: int, PE: int, p_budget: int,
                        NQ: int, R: int, block: int = ALLOC_BLOCK_N):
    """Pallas W-round allocation over router-major request arrays.

    Same contract as :func:`repro.kernels.ref.alloc_rounds_ref`.
    Rows are padded to a multiple of `block`; pad rows carry zero queue
    depth and are inert.
    """
    N = count_net.shape[0]
    PV = P * V
    n_pad = -N % block
    rows = N + n_pad
    cyc = jnp.asarray(cycle, jnp.int32).reshape(1, 1)
    out_net = _pad_rows(out_net.astype(jnp.int32), rows, -1)
    ej_net = _pad_rows(ej_net.astype(jnp.int32), rows)
    space_net = _pad_rows(space_net.astype(jnp.int32), rows)
    count_net = _pad_rows(count_net.astype(jnp.int32), rows)
    out_src = _pad_rows(out_src.astype(jnp.int32), rows, -1)
    ej_src = _pad_rows(ej_src.astype(jnp.int32), rows)
    space_src = _pad_rows(space_src.astype(jnp.int32), rows)
    count_src = _pad_rows(count_src.astype(jnp.int32), rows)
    epr = _pad_rows(epr_index.reshape(-1, 1).astype(jnp.int32), rows, -1)

    grid = (rows // block,)
    b3n = pl.BlockSpec((block, PV, W), lambda i: (i, 0, 0))
    b3s = pl.BlockSpec((block, PE, W), lambda i: (i, 0, 0))
    b2n = pl.BlockSpec((block, PV), lambda i: (i, 0))
    b2s = pl.BlockSpec((block, PE), lambda i: (i, 0))
    b2p = pl.BlockSpec((block, P), lambda i: (i, 0))
    b1 = pl.BlockSpec((block, 1), lambda i: (i, 0))
    bc = pl.BlockSpec((1, 1), lambda i: (0, 0))
    outs = pl.pallas_call(
        functools.partial(_alloc_kernel, W=W, P=P, V=V, PE=PE,
                          p_budget=p_budget, NQ=NQ, R=R, BN=block),
        grid=grid,
        in_specs=[bc, b3n, b3n, b3n, b2n, b3s, b3s, b3s, b2s, b1],
        out_specs=[b2n, b2n, b2s, b2s, b2p],
        out_shape=[
            jax.ShapeDtypeStruct((rows, PV), jnp.int32),
            jax.ShapeDtypeStruct((rows, PV), jnp.int32),
            jax.ShapeDtypeStruct((rows, PE), jnp.int32),
            jax.ShapeDtypeStruct((rows, PE), jnp.int32),
            jax.ShapeDtypeStruct((rows, P), jnp.int32),
        ],
        interpret=_interpret_mode(),
    )(cyc, out_net, ej_net, space_net, count_net,
      out_src, ej_src, space_src, count_src, epr)
    return tuple(o[:N] for o in outs)


def alloc_rounds(cycle, out_net, ej_net, space_net, count_net,
                 out_src, ej_src, space_src, count_src, epr_index,
                 *, W: int, P: int, V: int, PE: int, p_budget: int,
                 NQ: int, R: int, use_pallas: bool = False):
    """Dispatch between the Pallas kernel and the pure-jnp oracle.

    Lane axis (DESIGN.md §10): request arrays may carry one extra
    LEADING lane dimension ([L, N, PV, W] etc. — detected by rank).
    Lanes are mapped with jax.vmap, under which the Pallas grid grows a
    trailing lane dimension (`pl.program_id(0)` still indexes router
    blocks, so the in-kernel `row0` priority math is untouched); each
    lane's grants are bit-identical to a single-lane call
    (tests/test_sweep.py).  `cycle` may be scalar (shared) or [L];
    `epr_index` is placement-derived and always lane-invariant.
    """
    fn = alloc_rounds_pallas if use_pallas else ref.alloc_rounds_ref
    if out_net.ndim == 4:
        cycle = jnp.asarray(cycle)
        lane_fn = functools.partial(
            fn, W=W, P=P, V=V, PE=PE, p_budget=p_budget, NQ=NQ, R=R)
        return jax.vmap(
            lane_fn,
            in_axes=((0 if cycle.ndim else None,)
                     + (0,) * 8 + (None,)))(
            cycle, out_net, ej_net, space_net, count_net,
            out_src, ej_src, space_src, count_src, epr_index)
    return fn(cycle, out_net, ej_net, space_net, count_net,
              out_src, ej_src, space_src, count_src, epr_index,
              W=W, P=P, V=V, PE=PE, p_budget=p_budget, NQ=NQ, R=R)


# ------------------------------------------------------------ UGAL score --
def _ugal_kernel(lm_ref, lv_ref, om_ref, ov_ref, best_ref,
                 *, ugal_g, unreach, big):
    best_ref[...] = ref._ugal_score_math(
        lm_ref[...], lv_ref[...], om_ref[...], ov_ref[...],
        ugal_g=ugal_g, unreach=unreach, big=big)


@functools.partial(jax.jit, static_argnames=(
    "ugal_g", "unreach", "big", "block"))
def ugal_select_pallas(len_min, len_val, occ_min, occ_val,
                       *, ugal_g: bool, unreach: int, big: int,
                       block: int = UGAL_BLOCK_E):
    """Pallas UGAL/VAL candidate select; same contract as
    :func:`repro.kernels.ref.ugal_select_ref`.  Pad rows get
    len = unreach, score BIG everywhere, and are sliced off."""
    E = len_min.shape[0]
    C = len_val.shape[1]
    rows = E + (-E % block)
    lm = _pad_rows(len_min.reshape(-1, 1).astype(jnp.int32), rows, unreach)
    lv = _pad_rows(len_val.astype(jnp.int32), rows, unreach)
    om = _pad_rows(occ_min.reshape(-1, 1).astype(jnp.int32), rows)
    ov = _pad_rows(occ_val.astype(jnp.int32), rows)

    grid = (rows // block,)
    b1 = pl.BlockSpec((block, 1), lambda i: (i, 0))
    bC = pl.BlockSpec((block, C), lambda i: (i, 0))
    best = pl.pallas_call(
        functools.partial(_ugal_kernel, ugal_g=ugal_g, unreach=unreach,
                          big=big),
        grid=grid,
        in_specs=[b1, bC, b1, bC],
        out_specs=b1,
        out_shape=jax.ShapeDtypeStruct((rows, 1), jnp.int32),
        interpret=_interpret_mode(),
    )(lm, lv, om, ov)
    return best[:E, 0]


def ugal_select(len_min, len_val, occ_min, occ_val,
                *, ugal_g: bool, unreach: int, big: int,
                use_pallas: bool = False):
    """Dispatch between the Pallas kernel and the pure-jnp oracle.

    As with :func:`alloc_rounds`, one extra leading lane axis is
    accepted ([L, E] / [L, E, C]) and vmapped, bit-identically per
    lane."""
    fn = ugal_select_pallas if use_pallas else ref.ugal_select_ref
    if len_min.ndim == 2:
        lane_fn = functools.partial(fn, ugal_g=ugal_g, unreach=unreach,
                                    big=big)
        return jax.vmap(lane_fn)(len_min, len_val, occ_min, occ_val)
    return fn(len_min, len_val, occ_min, occ_val,
              ugal_g=ugal_g, unreach=unreach, big=big)
