"""Deterministic synthetic LM data pipeline.

Design goals (fault-tolerance substrate):
  - stateless addressing: batch(step) is a pure function of (seed, step,
    shard) — restart at step k reproduces the exact stream, so checkpoint
    resume is bit-exact without persisting pipeline state;
  - sharded: each data-parallel process draws only its shard;
  - background prefetch (host thread) to overlap host->device transfer.

The generator produces a Zipf-ish token distribution with local n-gram
structure so losses move (pure uniform tokens make optimizers look dead).
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticLM", "Prefetcher"]


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, n_shards: int = 1, shard: int = 0,
                 frontend: Optional[str] = None, n_front: int = 0,
                 d_model: int = 0):
        assert global_batch % n_shards == 0
        self.vocab, self.seq_len = vocab, seq_len
        self.batch = global_batch // n_shards
        self.seed, self.n_shards, self.shard = seed, n_shards, shard
        self.frontend, self.n_front, self.d_model = frontend, n_front, d_model

    def batch_at(self, step: int) -> dict:
        """Pure function of step (restart-reproducible)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        z = rng.zipf(1.3, size=(self.batch, self.seq_len)).astype(np.int64)
        toks = (z - 1) % self.vocab
        # inject local structure: every 2nd token repeats prev with p=0.3
        rep = rng.random((self.batch, self.seq_len)) < 0.3
        toks[:, 1:] = np.where(rep[:, 1:], toks[:, :-1], toks[:, 1:])
        out = dict(tokens=jnp.asarray(toks, jnp.int32))
        if self.frontend == "vision_stub":
            out["patches"] = jnp.asarray(
                rng.standard_normal((self.batch, self.n_front,
                                     self.d_model), np.float32) * 0.02)
        elif self.frontend == "audio_stub":
            out["frames"] = jnp.asarray(
                rng.standard_normal((self.batch, self.n_front,
                                     self.d_model), np.float32) * 0.02)
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Host-thread prefetch of upcoming batches (overlap data gen with
    device compute)."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self.q.put((step, self.source.batch_at(step)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
