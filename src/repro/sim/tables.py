"""Dense JAX-consumable routing/port tables derived from a Topology."""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.routing import RoutingTables, build_routing
from ..core.topology import Topology

__all__ = ["SimTables"]


@dataclasses.dataclass
class SimTables:
    """Everything the engine needs, as host numpy (moved to device lazily).

    Ports of router r: 0..deg(r)-1 network ports (order = sorted neighbor
    ids); the ejection "port" is virtual (engine-side).
    """
    topo: Topology
    n_routers: int
    P: int                        # max network ports (k')
    p: int                        # endpoints per endpoint-router
    nbr: np.ndarray               # [N, P] neighbor router (-1 pad)
    rev_port: np.ndarray          # [N, P] port index at nbr pointing back
    port_toward: np.ndarray       # [N, N] first-hop port of MIN route (-1 self)
    dist: np.ndarray              # [N, N] int16
    ep_router: np.ndarray         # [N_ep] router id of each endpoint
    ecmp_ports: Optional[np.ndarray] = None   # [N, N, M] equal-cost ports

    @property
    def n_endpoints(self) -> int:
        return len(self.ep_router)

    @classmethod
    def build(cls, topo: Topology, rt: Optional[RoutingTables] = None,
              ecmp: bool = False) -> "SimTables":
        rt = rt or build_routing(topo, use_pallas=False,
                                 equal_cost_sets=ecmp)
        n = topo.n_routers
        P = topo.network_radix
        nbr = topo.neighbor_lists(pad_to=P).astype(np.int32)

        # port index of a given neighbor: inverse of nbr
        port_of = np.full((n, n), -1, dtype=np.int32)
        for r in range(n):
            for o in range(P):
                v = nbr[r, o]
                if v >= 0:
                    port_of[r, v] = o

        rev_port = np.full((n, P), -1, dtype=np.int32)
        for r in range(n):
            for o in range(P):
                v = nbr[r, o]
                if v >= 0:
                    rev_port[r, o] = port_of[v, r]

        port_toward = np.full((n, n), -1, dtype=np.int32)
        nh = rt.next_hop
        rr = np.repeat(np.arange(n), n)
        tt = np.tile(np.arange(n), n)
        mask = nh.ravel() != np.arange(n).repeat(n)  # exclude self
        port_toward[rr[mask], tt[mask]] = port_of[rr[mask], nh.ravel()[mask]]

        ecmp_ports = None
        if ecmp:
            width = 0
            sets = rt.next_hops_all
            for r in range(n):
                for t in range(n):
                    width = max(width, len(sets[r][t]))
            ecmp_ports = np.full((n, n, width), -1, dtype=np.int32)
            for r in range(n):
                for t in range(n):
                    opts = sets[r][t]
                    for i, v in enumerate(opts):
                        ecmp_ports[r, t, i] = port_of[r, v]

        if topo.endpoint_mask is not None:
            ep_routers = np.nonzero(topo.endpoint_mask)[0]
        else:
            ep_routers = np.arange(n)
        ep_router = np.repeat(ep_routers, topo.p).astype(np.int32)

        return cls(topo=topo, n_routers=n, P=P, p=topo.p, nbr=nbr,
                   rev_port=rev_port, port_toward=port_toward,
                   dist=rt.dist.astype(np.int16), ep_router=ep_router,
                   ecmp_ports=ecmp_ports)
