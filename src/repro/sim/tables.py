"""Dense JAX-consumable routing/port tables derived from a Topology.

Fault model (DESIGN.md §8): `build(..., failed_edges=...)` rebuilds the
tables on the masked adjacency — port numbering stays that of the
HEALTHY fabric (sorted neighbor ids of the unmasked graph) so shapes
and port ids are comparable across masks; dead ports become `-1` pads
in `nbr`/`rev_port`, and `port_toward`/`ecmp_ports`/`dist` are
recomputed from the re-converged routing.  `with_failures(...,
rebuild=False)` instead only kills the ports and leaves the stale route
tables in place — the transient window before routing re-converges,
survivable only via the engine's ECMP fallback.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.routing import RoutingTables, build_routing
from ..core.topology import Topology, normalize_failed_edges

__all__ = ["SimTables"]


@dataclasses.dataclass
class SimTables:
    """Everything the engine needs, as host numpy (moved to device lazily).

    Ports of router r: 0..deg(r)-1 network ports (order = sorted neighbor
    ids of the healthy fabric); the ejection "port" is virtual
    (engine-side).  Dead ports (link failures) hold -1.
    """
    topo: Topology
    n_routers: int
    P: int                        # max network ports (k')
    p: int                        # endpoints per endpoint-router
    nbr: np.ndarray               # [N, P] neighbor router (-1 pad/dead)
    rev_port: np.ndarray          # [N, P] port index at nbr pointing back
    port_toward: np.ndarray       # [N, N] int16 first-hop MIN port (-1 self)
    dist: np.ndarray              # [N, N] int16 (UNREACH when cut off)
    ep_router: np.ndarray         # [N_ep] router id of each endpoint
    ecmp_ports: Optional[np.ndarray] = None   # [N, N, M] int16 equal-cost
    failed_edges: Optional[np.ndarray] = None  # [K, 2] mask these tables saw

    @property
    def n_endpoints(self) -> int:
        return len(self.ep_router)

    @classmethod
    def build(cls, topo: Topology, rt: Optional[RoutingTables] = None,
              ecmp: bool = False,
              failed_edges: Optional[np.ndarray] = None) -> "SimTables":
        if failed_edges is not None:
            failed_edges = normalize_failed_edges(failed_edges, topo)
        if rt is not None and failed_edges is not None:
            # a pre-built rt must have seen the same mask, or the port
            # tables would silently disagree with `failed_edges`
            have = rt.failed_edges
            assert have is not None and np.array_equal(
                np.sort(np.sort(have, axis=1), axis=0),
                np.sort(np.sort(failed_edges, axis=1), axis=0)), \
                "rt was not built with the given failed_edges mask"
        rt = rt or build_routing(topo, use_pallas=False,
                                 equal_cost_sets=ecmp,
                                 failed_edges=failed_edges)
        if failed_edges is None and rt.failed_edges is not None:
            failed_edges = rt.failed_edges
        n = topo.n_routers
        P = topo.network_radix
        # healthy port order, then kill failed links -> -1 pads
        nbr = topo.neighbor_lists(pad_to=P).astype(np.int32)
        if failed_edges is not None and len(failed_edges):
            dead = ~rt.adj                    # live adjacency from routing
            for r in range(n):
                for o in range(P):
                    v = nbr[r, o]
                    if v >= 0 and dead[r, v]:
                        nbr[r, o] = -1

        # port index of a given neighbor: inverse of nbr (live links only)
        port_of = np.full((n, n), -1, dtype=np.int32)
        for r in range(n):
            for o in range(P):
                v = nbr[r, o]
                if v >= 0:
                    port_of[r, v] = o

        rev_port = np.full((n, P), -1, dtype=np.int32)
        for r in range(n):
            for o in range(P):
                v = nbr[r, o]
                if v >= 0:
                    rev_port[r, o] = port_of[v, r]

        # the O(N^2) tables are int16 on host and device (DESIGN.md §9);
        # port indices < k' and distances <= UNREACH both fit easily
        port_toward = np.full((n, n), -1, dtype=np.int16)
        nh = rt.next_hop
        rr = np.repeat(np.arange(n), n)
        tt = np.tile(np.arange(n), n)
        # exclude self and unreachable (next_hop -1) targets
        mask = (nh.ravel() != np.arange(n).repeat(n)) & (nh.ravel() >= 0)
        port_toward[rr[mask], tt[mask]] = port_of[rr[mask], nh.ravel()[mask]]

        ecmp_ports = None
        if ecmp:
            width = 1
            sets = rt.next_hops_all
            for r in range(n):
                for t in range(n):
                    width = max(width, len(sets[r][t]))
            ecmp_ports = np.full((n, n, width), -1, dtype=np.int16)
            for r in range(n):
                for t in range(n):
                    opts = sets[r][t]
                    for i, v in enumerate(opts):
                        ecmp_ports[r, t, i] = port_of[r, v]

        if topo.endpoint_mask is not None:
            ep_routers = np.nonzero(topo.endpoint_mask)[0]
        else:
            ep_routers = np.arange(n)
        ep_router = np.repeat(ep_routers, topo.p).astype(np.int32)

        return cls(topo=topo, n_routers=n, P=P, p=topo.p, nbr=nbr,
                   rev_port=rev_port, port_toward=port_toward,
                   dist=rt.dist.astype(np.int16), ep_router=ep_router,
                   ecmp_ports=ecmp_ports, failed_edges=failed_edges)

    def with_failures(self, failed_edges,
                      rebuild: bool = True) -> "SimTables":
        """Degraded copy of these tables under an (additional) link mask.

        rebuild=True re-converges routing on the masked adjacency (the
        steady degraded state).  rebuild=False only marks the dead
        ports (-1 in nbr/rev_port) and keeps the stale port_toward /
        ecmp_ports / dist — the unconverged transient, where delivery
        relies on the engine's dead-port ECMP fallback.
        """
        fe = normalize_failed_edges(failed_edges, self.topo)
        if self.failed_edges is not None and len(self.failed_edges):
            fe = np.concatenate([self.failed_edges, fe], axis=0)
        if rebuild:
            return SimTables.build(self.topo, ecmp=self.ecmp_ports is not None,
                                   failed_edges=fe)
        nbr = self.nbr.copy()
        rev_port = self.rev_port.copy()
        dead = set(map(tuple, np.sort(fe, axis=1)))
        n = self.n_routers
        for r in range(n):
            for o in range(self.P):
                v = nbr[r, o]
                if v >= 0 and (min(r, v), max(r, v)) in dead:
                    nbr[r, o] = -1
                    rev_port[r, o] = -1
        return dataclasses.replace(self, nbr=nbr, rev_port=rev_port,
                                   failed_edges=fe)
