"""Dense JAX-consumable routing/port tables derived from a Topology.

Fault model (DESIGN.md §8): `build(..., failed_edges=...)` rebuilds the
tables on the masked adjacency — port numbering stays that of the
HEALTHY fabric (sorted neighbor ids of the unmasked graph) so shapes
and port ids are comparable across masks; dead ports become `-1` pads
in `nbr`/`rev_port`, and `port_toward`/`ecmp_ports`/`dist` are
recomputed from the re-converged routing.  `with_failures(...,
rebuild=False)` instead only kills the ports and leaves the stale route
tables in place — the transient window before routing re-converges,
survivable only via the engine's ECMP fallback.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.routing import RoutingTables, build_routing
from ..core.topology import Topology, normalize_failed_edges

__all__ = ["SimTables"]


@dataclasses.dataclass
class SimTables:
    """Everything the engine needs, as host numpy (moved to device lazily).

    Ports of router r: 0..deg(r)-1 network ports (order = sorted neighbor
    ids of the healthy fabric); the ejection "port" is virtual
    (engine-side).  Dead ports (link failures) hold -1.

    Lane stacking (DESIGN.md §10): :meth:`stack` bundles L same-shape
    table sets (e.g. per-failure-sample degraded rebuilds of one
    topology) into one object whose per-lane arrays carry a leading
    [L] axis (``lanes > 1``); :meth:`lane` slices one lane back out.
    Stacked tables are consumed by `repro.sim.sweep`, never by
    `SwitchCore` directly.
    """
    topo: Topology
    n_routers: int
    P: int                        # max network ports (k')
    p: int                        # endpoints per endpoint-router
    nbr: np.ndarray               # [N, P] neighbor router (-1 pad/dead)
    rev_port: np.ndarray          # [N, P] port index at nbr pointing back
    port_toward: np.ndarray       # [N, N] int16 first-hop MIN port (-1 self)
    dist: np.ndarray              # [N, N] int16 (UNREACH when cut off)
    ep_router: np.ndarray         # [N_ep] router id of each endpoint
    ecmp_ports: Optional[np.ndarray] = None   # [N, N, M] int16 equal-cost
    failed_edges: Optional[np.ndarray] = None  # [K, 2] mask these tables saw
    lanes: int = 1                # >1: per-lane arrays have a leading L axis

    # arrays that grow the leading lane axis under stack() — exactly the
    # ones SwitchCore moves to device and the sweep engine feeds to
    # jax.vmap as traced operands
    LANE_FIELDS = ("nbr", "rev_port", "port_toward", "dist", "ecmp_ports")

    @property
    def n_endpoints(self) -> int:
        return len(self.ep_router)

    @classmethod
    def stack(cls, tables: "list[SimTables]") -> "SimTables":
        """Bundle L single-lane table sets into one lane-stacked object.

        All lanes must describe the same fabric shape: identical
        router/port/endpoint counts and endpoint placement (true by
        construction for failure-sample rebuilds of one topology).
        ``ecmp_ports`` widths may differ per lane (equal-cost set sizes
        depend on the mask); they are right-padded with -1 to the
        widest lane, which is grant-for-grant invariant in the engine
        (pad ports score BIG and can never win an argmin).
        """
        assert len(tables) >= 1, "stack() needs at least one lane"
        base = tables[0]
        for t in tables:
            assert t.lanes == 1, "stack() takes single-lane tables"
            assert (t.n_routers, t.P, t.p) == (base.n_routers, base.P,
                                               base.p), \
                "lane shape mismatch (different topologies?)"
            assert np.array_equal(t.ep_router, base.ep_router), \
                "lanes must share endpoint placement"
            assert (t.ecmp_ports is None) == (base.ecmp_ports is None), \
                "mixed ecmp/non-ecmp lanes"
        if base.ecmp_ports is not None:
            width = max(t.ecmp_ports.shape[-1] for t in tables)

            def pad_ecmp(e):
                if e.shape[-1] == width:
                    return e
                pad = np.full(e.shape[:-1] + (width - e.shape[-1],), -1,
                              dtype=e.dtype)
                return np.concatenate([e, pad], axis=-1)
            ecmp = np.stack([pad_ecmp(t.ecmp_ports) for t in tables])
        else:
            ecmp = None
        return cls(
            topo=base.topo, n_routers=base.n_routers, P=base.P, p=base.p,
            nbr=np.stack([t.nbr for t in tables]),
            rev_port=np.stack([t.rev_port for t in tables]),
            port_toward=np.stack([t.port_toward for t in tables]),
            dist=np.stack([t.dist for t in tables]),
            ep_router=base.ep_router, ecmp_ports=ecmp,
            failed_edges=None, lanes=len(tables))

    def lane(self, i: int) -> "SimTables":
        """Single-lane view of lane `i` of a stacked table set."""
        if self.lanes == 1:
            assert i == 0, i
            return self
        return dataclasses.replace(
            self, nbr=self.nbr[i], rev_port=self.rev_port[i],
            port_toward=self.port_toward[i], dist=self.dist[i],
            ecmp_ports=(None if self.ecmp_ports is None
                        else self.ecmp_ports[i]),
            lanes=1)

    @classmethod
    def build(cls, topo: Topology, rt: Optional[RoutingTables] = None,
              ecmp: bool = False,
              failed_edges: Optional[np.ndarray] = None) -> "SimTables":
        if failed_edges is not None:
            failed_edges = normalize_failed_edges(failed_edges, topo)
        if rt is not None and failed_edges is not None:
            # a pre-built rt must have seen the same mask, or the port
            # tables would silently disagree with `failed_edges`
            have = rt.failed_edges
            assert have is not None and np.array_equal(
                np.sort(np.sort(have, axis=1), axis=0),
                np.sort(np.sort(failed_edges, axis=1), axis=0)), \
                "rt was not built with the given failed_edges mask"
        rt = rt or build_routing(topo, use_pallas=False,
                                 equal_cost_sets=ecmp,
                                 failed_edges=failed_edges)
        if failed_edges is None and rt.failed_edges is not None:
            failed_edges = rt.failed_edges
        n = topo.n_routers
        P = topo.network_radix
        # healthy port order, then kill failed links -> -1 pads
        nbr = topo.neighbor_lists(pad_to=P).astype(np.int32)
        if failed_edges is not None and len(failed_edges):
            dead = ~rt.adj                    # live adjacency from routing
            for r in range(n):
                for o in range(P):
                    v = nbr[r, o]
                    if v >= 0 and dead[r, v]:
                        nbr[r, o] = -1

        # port index of a given neighbor: inverse of nbr (live links only)
        port_of = np.full((n, n), -1, dtype=np.int32)
        for r in range(n):
            for o in range(P):
                v = nbr[r, o]
                if v >= 0:
                    port_of[r, v] = o

        rev_port = np.full((n, P), -1, dtype=np.int32)
        for r in range(n):
            for o in range(P):
                v = nbr[r, o]
                if v >= 0:
                    rev_port[r, o] = port_of[v, r]

        # the O(N^2) tables are int16 on host and device (DESIGN.md §9);
        # port indices < k' and distances <= UNREACH both fit easily
        port_toward = np.full((n, n), -1, dtype=np.int16)
        nh = rt.next_hop
        rr = np.repeat(np.arange(n), n)
        tt = np.tile(np.arange(n), n)
        # exclude self and unreachable (next_hop -1) targets
        mask = (nh.ravel() != np.arange(n).repeat(n)) & (nh.ravel() >= 0)
        port_toward[rr[mask], tt[mask]] = port_of[rr[mask], nh.ravel()[mask]]

        ecmp_ports = None
        if ecmp:
            width = 1
            sets = rt.next_hops_all
            for r in range(n):
                for t in range(n):
                    width = max(width, len(sets[r][t]))
            ecmp_ports = np.full((n, n, width), -1, dtype=np.int16)
            for r in range(n):
                for t in range(n):
                    opts = sets[r][t]
                    for i, v in enumerate(opts):
                        ecmp_ports[r, t, i] = port_of[r, v]

        if topo.endpoint_mask is not None:
            ep_routers = np.nonzero(topo.endpoint_mask)[0]
        else:
            ep_routers = np.arange(n)
        ep_router = np.repeat(ep_routers, topo.p).astype(np.int32)

        return cls(topo=topo, n_routers=n, P=P, p=topo.p, nbr=nbr,
                   rev_port=rev_port, port_toward=port_toward,
                   dist=rt.dist.astype(np.int16), ep_router=ep_router,
                   ecmp_ports=ecmp_ports, failed_edges=failed_edges)

    def with_failures(self, failed_edges,
                      rebuild: bool = True) -> "SimTables":
        """Degraded copy of these tables under an (additional) link mask.

        rebuild=True re-converges routing on the masked adjacency (the
        steady degraded state).  rebuild=False only marks the dead
        ports (-1 in nbr/rev_port) and keeps the stale port_toward /
        ecmp_ports / dist — the unconverged transient, where delivery
        relies on the engine's dead-port ECMP fallback.
        """
        fe = normalize_failed_edges(failed_edges, self.topo)
        if self.failed_edges is not None and len(self.failed_edges):
            fe = np.concatenate([self.failed_edges, fe], axis=0)
        if rebuild:
            return SimTables.build(self.topo, ecmp=self.ecmp_ports is not None,
                                   failed_edges=fe)
        nbr = self.nbr.copy()
        rev_port = self.rev_port.copy()
        dead = set(map(tuple, np.sort(fe, axis=1)))
        n = self.n_routers
        for r in range(n):
            for o in range(self.P):
                v = nbr[r, o]
                if v >= 0 and (min(r, v), max(r, v)) in dead:
                    nbr[r, o] = -1
                    rev_port[r, o] = -1
        return dataclasses.replace(self, nbr=nbr, rev_port=rev_port,
                                   failed_edges=fe)
