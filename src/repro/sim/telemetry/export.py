"""Render telemetry snapshots: heatmaps, tables, Chrome-trace JSON.

Two consumers:

  - text/JSON reporting — `telemetry_summary` feeds WorkloadReport
    tables, `write_channel_heatmap` emits the per-lane channel-load
    JSON that benchmarks/CI archive next to BENCH_engine.json;
  - perfetto — `chrome_trace` / `write_chrome_trace` emit the Chrome
    trace-event JSON format (https://ui.perfetto.dev loads it
    directly): one pid per traced subsystem, routers as tid tracks,
    flit lifetimes as "X" complete spans on their source router, hop
    arrivals as "i" instants on the routers they touch, plus optional
    collective phase markers and a delivered-flits counter track.
    Cycles map 1:1 to microseconds.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from .counters import CountersSnapshot
from .trace import PORT_EP, build_spans

__all__ = ["hottest_channels", "router_table", "telemetry_summary",
           "channel_load_doc", "write_channel_heatmap",
           "chrome_trace", "write_chrome_trace"]


# ---------------------------------------------------------------------------
# counters -> tables / heatmap docs
# ---------------------------------------------------------------------------

def hottest_channels(cs: CountersSnapshot, top: int = 10) -> List[dict]:
    """Top channels by utilisation: [{router, port, flits, load}, ...]."""
    load = cs.channel_load()
    flat = np.argsort(load, axis=None)[::-1][:top]
    rows = []
    for k in flat:
        r, o = np.unravel_index(k, load.shape)
        if cs.chan_flits[r, o] == 0:
            break
        rows.append({"router": int(r), "port": int(o),
                     "flits": int(cs.chan_flits[r, o]),
                     "load": float(load[r, o])})
    return rows


def router_table(cs: CountersSnapshot, top: int = 10) -> List[dict]:
    """Busiest routers by mean queue occupancy, with their congestion
    and delivery stats."""
    occ = cs.mean_queue_occupancy()
    deny = cs.deny_rate()
    lat = cs.mean_ej_latency()
    order = np.argsort(occ)[::-1][:top]
    rows = []
    for r in order:
        rows.append({
            "router": int(r),
            "mean_occupancy": float(occ[r]),
            "max_queue_depth": int(cs.occ_max[r]),
            "deny_rate": float(deny[r]),
            "ejected": int(cs.ej_count[r]),
            "mean_ej_latency": (float(lat[r])
                                if np.isfinite(lat[r]) else None),
            "max_ej_latency": int(cs.ej_lat_max[r]),
        })
    return rows


def telemetry_summary(cs: CountersSnapshot, top: int = 5) -> List[str]:
    """Human-readable summary lines (appended to WorkloadReport.table)."""
    total = int(cs.chan_flits.sum())
    live = cs.chan_flits > 0
    lines = [
        "-- telemetry ({} cycles) --".format(cs.cycles),
        "channel flits {:>10d}   live channels {:d}   mean load {:.4f}"
        .format(total, int(live.sum()),
                float(cs.channel_load()[live].mean()) if live.any()
                else 0.0),
        "grants {:>14d}   denies {:d}   deny rate {:.4f}".format(
            int(cs.alloc_grant.sum()), int(cs.alloc_deny.sum()),
            float(cs.alloc_deny.sum())
            / max(int((cs.alloc_grant + cs.alloc_deny).sum()), 1)),
        "routes min/val {:>6d} / {:d}".format(
            int(cs.route_min.sum()), int(cs.route_val.sum())),
    ]
    for row in hottest_channels(cs, top=top):
        lines.append(
            "  hot chan r{:>4d} p{:>3d}  load {:.4f}  ({} flits)".format(
                row["router"], row["port"], row["load"], row["flits"]))
    return lines


def channel_load_doc(snapshots: Sequence[Any],
                     lane_labels: Optional[Sequence[str]] = None) -> dict:
    """Heatmap document for one or more lanes' counter snapshots.

    `snapshots` holds TelemetrySnapshot (or CountersSnapshot) objects —
    one per sweep lane (or a single-run singleton).  The JSON is a
    dense [N, P] load matrix per lane plus the hot-spot tables, which
    is all a plotting frontend needs."""
    lanes = []
    for i, snap in enumerate(snapshots):
        cs = getattr(snap, "counters", snap)
        if cs is None:
            continue
        lanes.append({
            "label": (lane_labels[i] if lane_labels is not None
                      else "lane{}".format(i)),
            "cycles": cs.cycles,
            "channel_load": np.round(cs.channel_load(), 6).tolist(),
            "hottest_channels": hottest_channels(cs),
            "router_table": router_table(cs),
        })
    return {"kind": "repro.telemetry.channel_load",
            "n_lanes": len(lanes), "lanes": lanes}


def write_channel_heatmap(path: str, snapshots: Sequence[Any],
                          lane_labels: Optional[Sequence[str]] = None
                          ) -> dict:
    doc = channel_load_doc(snapshots, lane_labels)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


# ---------------------------------------------------------------------------
# trace -> perfetto / Chrome trace-event JSON
# ---------------------------------------------------------------------------

_PID_FLITS = 1       # flit lifetime spans, per source router
_PID_HOPS = 2        # hop-arrival instants, per touched router
_PID_RUN = 3         # run-level tracks: phase markers, counters


def _thread_meta(pid: int, tid: int, name: str) -> dict:
    return {"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
            "args": {"name": name}}


def chrome_trace(snapshot: Any, phase_marks: Optional[Sequence] = None,
                 per_cycle_counter: Optional[np.ndarray] = None,
                 counter_name: str = "delivered/cycle",
                 counter_stride: int = 50) -> dict:
    """TelemetrySnapshot -> Chrome trace-event JSON dict.

    `phase_marks` is an optional [(cycle, label), ...] list (e.g.
    collective phase starts from a workload schedule);
    `per_cycle_counter` (e.g. WorkloadResult.per_cycle_delivered) is
    downsampled every `counter_stride` cycles onto a "C" counter track.
    One simulated cycle is rendered as one microsecond."""
    events: List[dict] = []
    meta: Dict[int, dict] = {}
    names = {_PID_FLITS: "flits (by source router)",
             _PID_HOPS: "hop arrivals (by router)",
             _PID_RUN: "run"}
    for pid, name in names.items():
        events.append({"ph": "M", "name": "process_name", "pid": pid,
                       "args": {"name": name}})

    def track(pid: int, tid: int, label: str):
        if (pid, tid) not in meta:
            meta[(pid, tid)] = _thread_meta(pid, tid, label)

    spans = build_spans(snapshot.events) if snapshot.events is not None \
        else []
    for sp in spans:
        start = sp["start"]
        if start is None and sp["hops"]:
            start = sp["hops"][0][0]
        end = sp["end"]
        if end is None:
            end = max([start or 0]
                      + [c for c, _, _ in sp["hops"]])
        src = sp["src_router"]
        if src is None:
            src = sp["hops"][0][1] if sp["hops"] else -1
        if start is None:
            continue
        track(_PID_FLITS, src, "router {}".format(src))
        events.append({
            "ph": "X", "pid": _PID_FLITS, "tid": src,
            "name": "msg {} -> r{}".format(sp["msg"], sp["dst"]),
            "ts": start, "dur": max(end - start, 1),
            "args": {"msg": sp["msg"], "dst": sp["dst"],
                     "phase": "MIN" if sp["phase"] == 1 else "VAL",
                     "hops": sp["n_hops"],
                     "complete": sp["end"] is not None}})
        for cyc, router, port in sp["hops"]:
            track(_PID_HOPS, router, "router {}".format(router))
            events.append({
                "ph": "i", "s": "t", "pid": _PID_HOPS, "tid": router,
                "name": "msg {} @p{}".format(
                    sp["msg"], port if port != PORT_EP else "EP"),
                "ts": cyc})

    if phase_marks:
        track(_PID_RUN, 0, "phases")
        for cyc, label in phase_marks:
            events.append({"ph": "i", "s": "p", "pid": _PID_RUN,
                           "tid": 0, "name": str(label),
                           "ts": int(cyc)})
    if per_cycle_counter is not None:
        arr = np.asarray(per_cycle_counter)
        for c in range(0, len(arr), max(counter_stride, 1)):
            chunk = arr[c:c + counter_stride]
            events.append({"ph": "C", "pid": _PID_RUN, "tid": 0,
                           "name": counter_name, "ts": c,
                           "args": {"value": float(chunk.mean())}})

    return {"traceEvents": list(meta.values()) + events,
            "displayTimeUnit": "ms",
            "otherData": {"source": "repro.sim.telemetry",
                          "cycles": int(snapshot.cycles),
                          "events_dropped": int(snapshot.events_dropped),
                          "n_spans": len(spans)}}


def write_chrome_trace(path: str, snapshot: Any, **kw) -> dict:
    doc = chrome_trace(snapshot, **kw)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc
