"""In-scan fabric telemetry: counters + flit-sampled tracing (DESIGN.md §12).

The engines report end-of-run scalars; the paper's interesting claims
(Fig 6 saturation, §VI congestion, Table III degraded-mode inflation)
are about *where* load concentrates.  This package threads an opt-in,
shape-static observability layer through the scan carry of BOTH engines
(`repro.sim.engine.simulate` and the closed-loop workload engine):

  - `counters`  — per-router / per-channel int32 accumulators (channel
    flits-forwarded, per-allocation-round grant/deny, MIN-vs-VAL route
    choices, queue-occupancy sum/max, ejection latency sum/count/max
    per destination router), updated with pure data-parallel ops (no
    scatters) so the lane-batched sweep engine reports per-lane
    counters from ONE compile (DESIGN.md §10);
  - `trace`     — a deterministic hash-sampled subset of flits writes
    per-hop event records (cycle, router, port, phase, kind) into a
    fixed-size ring buffer carried through the scan, decoded host-side
    into per-flit span trees;
  - `export`    — channel-load heatmaps, per-router tables (feeding
    `WorkloadReport` / `MultiJobResult`) and perfetto-compatible
    Chrome-trace JSON (routers as tracks, flit spans, phase markers)
    viewable at https://ui.perfetto.dev.

Contract: with `TelemetryConfig()` (everything off) the carry gains an
EMPTY pytree — zero extra arrays, identical jaxpr, bit-exact results
vs the pre-telemetry engines (tests/test_telemetry.py re-runs the
golden-pinned configs).  With telemetry on, the additions are DATA
ONLY: no RNG is consumed and no engine value depends on a telemetry
value, so core results stay bit-identical with counters enabled too.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import numpy as np

from .counters import (CounterState, CountersSnapshot, decode_counters,
                       init_counters)
from .trace import (EVENT_DTYPE, TraceState, build_spans, decode_trace,
                    init_trace, sampled_fids)

__all__ = [
    "TelemetryConfig", "TelemetryState", "TelemetrySnapshot",
    "init_state", "snapshot",
    "CounterState", "CountersSnapshot", "TraceState",
    "build_spans", "sampled_fids", "EVENT_DTYPE",
]


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Opt-in telemetry knobs; part of the engines' static config.

    Joins `SimConfig.static_key()` / `WorkloadSimConfig.static_key()`:
    flipping any field compiles a separate executable (the carry pytree
    changes shape), so telemetry-off runs never pay for the layer.
    """
    counters: bool = False
    trace: bool = False
    # sample 1 / 2**shift of flows (messages in the closed loop, packets
    # in the open loop); 0 traces everything
    trace_sample_shift: int = 3
    # ring-buffer capacity in events; per-cycle overflow is dropped and
    # counted, across cycles the ring wraps (oldest events overwritten)
    trace_capacity: int = 4096

    def __post_init__(self):
        assert 0 <= self.trace_sample_shift < 32, self.trace_sample_shift
        assert self.trace_capacity > 0, self.trace_capacity

    @property
    def enabled(self) -> bool:
        return self.counters or self.trace

    def static_key(self) -> tuple:
        return (self.counters, self.trace, self.trace_sample_shift,
                self.trace_capacity)


class TelemetryState(NamedTuple):
    """The telemetry element of a scan carry.  Each member is either a
    per-feature state pytree or `()` when that feature is off; the
    whole element is `()` (no leaves at all) when telemetry is off."""
    counters: Any            # CounterState | ()
    trace: Any               # TraceState   | ()


@dataclasses.dataclass
class TelemetrySnapshot:
    """Host-side decode of a run's final TelemetryState."""
    cycles: int                                   # normalisation span
    counters: Optional[CountersSnapshot] = None
    events: Optional[np.ndarray] = None           # structured EVENT_DTYPE
    events_dropped: int = 0                       # same-cycle overflow

    def spans(self) -> list:
        """Per-flit span trees of the traced events (trace.build_spans)."""
        if self.events is None:
            return []
        return build_spans(self.events)


def init_state(tel: TelemetryConfig, core) -> Any:
    """Initial telemetry carry element for `core` (a SwitchCore):
    `()` when off — the carry pytree gains no leaves and the compiled
    step is unchanged."""
    if not tel.enabled:
        return ()
    return TelemetryState(
        counters=init_counters(core) if tel.counters else (),
        trace=init_trace(tel.trace_capacity) if tel.trace else ())


def snapshot(tel: TelemetryConfig, state: Any,
             cycles: int) -> Optional[TelemetrySnapshot]:
    """Decode a final telemetry carry element into host arrays.
    `cycles` is the span counters are normalised over (cfg.cycles for
    the open loop, the trimmed cycles_run for closed-loop runs)."""
    if tel is None or not tel.enabled:
        return None
    cs = decode_counters(state.counters, cycles) if tel.counters else None
    ev, dropped = (decode_trace(state.trace) if tel.trace else (None, 0))
    return TelemetrySnapshot(cycles=int(cycles), counters=cs,
                             events=ev, events_dropped=dropped)
