"""Flit-sampled tracing: in-carry event ring buffer (DESIGN.md §12).

Sampling.  The packed flit record has no spare bits (word 2 uses 31 of
32, and bit 31 must stay clear for the arithmetic shift in `pk_msg`),
so instead of tagging sampled flits we recompute a deterministic hash
at every event site from fields that are INVARIANT across hops:

  - closed loop: the packed MSG field (`msg_sampler`) — all flits and
    all hops of one message sample together, giving whole-message span
    trees;
  - open loop: the flow key (word 0 = dst|inter, word 1 = inject
    cycle; `flow_sampler`) — a packet's identity for its lifetime.

A flow is sampled iff the low `shift` bits of a mixed 32-bit hash are
zero (rate 1/2**shift, shift 0 = trace everything); the same hash is
exposed host-side (`sampled_fids`) so tests and decoders can predict
exactly which messages were traced.

Ring buffer.  Events are EV=6 int32 words:

  word 0  cycle
  word 1  router | port << 16     (port: input port for hops/ejects,
                                   PORT_EP = 0x7FFF for endpoint-side
                                   inject / source-queue-eject events)
  word 2  packed MSG field (0 in the open loop)
  word 3  inject cycle (pk_time)
  word 4  dst | hops << 15 | phase << 21 | kind << 22
  word 5  intermediate router (pk_inter)

Word 5 completes the flit's hop-invariant identity: span grouping keys
on (msg, inject cycle, dst, inter), which is unique per message in the
closed loop and collision-free per flow in the open loop (where msg is
always 0, two same-cycle packets to the same destination still differ
in their VAL intermediate except for genuinely indistinguishable
MIN-phase twins).

Each cycle's candidate events (arrivals, ejections, injections) are
masked by site-validity & sampling, ranked by an exclusive cumsum, and
scattered at `(n + rank) % capacity` — one scatter per cycle, distinct
indices, deterministic.  Events beyond `capacity` within ONE cycle are
dropped (and counted); across cycles the ring wraps, keeping the most
recent `capacity` events.  This is the only scatter telemetry adds,
which is why tracing (unlike counters) is priced for single-lane runs
— under the sweep engine's lane vmap a batched scatter is the hottest
lowering on CPU (DESIGN.md §9/§10).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from ..packed import (pk_dst, pk_flow_key, pk_hops, pk_inter, pk_msg,
                      pk_phase, pk_time)

__all__ = ["EV", "PORT_EP", "KIND_INJECT", "KIND_HOP", "KIND_EJECT",
           "TraceState", "init_trace", "msg_sampler", "flow_sampler",
           "sampled_fids", "pack_events", "ring_append", "trace_alloc",
           "EVENT_DTYPE", "decode_trace", "build_spans"]

EV = 6                       # int32 words per event record
PORT_EP = 0x7FFF             # port marker for endpoint-side events
KIND_INJECT = 0              # flit enters its source queue
KIND_HOP = 1                 # flit arrives at a router input port
KIND_EJECT = 2               # flit delivered (net queue or src queue)


class TraceState(NamedTuple):
    buf: jnp.ndarray          # [capacity, EV] int32
    n: jnp.ndarray            # scalar int32: events written (monotone)
    dropped: jnp.ndarray      # scalar int32: same-cycle overflow drops


def init_trace(capacity: int) -> TraceState:
    return TraceState(jnp.zeros((capacity, EV), jnp.int32),
                      jnp.int32(0), jnp.int32(0))


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def _mix32(x):
    """32-bit integer finalizer (xor-shift-multiply avalanche)."""
    h = jnp.asarray(x).astype(jnp.uint32)
    h = (h ^ (h >> 16)) * jnp.uint32(0x45D9F3B)
    h = (h ^ (h >> 16)) * jnp.uint32(0x45D9F3B)
    return h ^ (h >> 16)


def _sampled(key, shift: int):
    return (_mix32(key) & jnp.uint32((1 << shift) - 1)) == 0


def msg_sampler(shift: int):
    """Closed loop: sample whole messages by the packed MSG field."""
    return lambda pkt: _sampled(pk_msg(pkt), shift)


def flow_sampler(shift: int):
    """Open loop: sample packets by the hop-invariant flow key."""
    def sample(pkt):
        w0, w1 = pk_flow_key(pkt)
        key = (w0.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
               ^ w1.astype(jnp.uint32))
        return _sampled(key, shift)
    return sample


def sampled_fids(fids, shift: int) -> np.ndarray:
    """Host-side predicate: which MSG-field values `msg_sampler` traces
    (bool array, same shape as `fids`)."""
    return np.asarray(_sampled(np.asarray(fids, np.int64) & 0xFFFFFFFF,
                               shift))


# ---------------------------------------------------------------------------
# event collection (device side)
# ---------------------------------------------------------------------------

def pack_events(cycle, kind: int, router, port, pkt):
    """Pack one event site into flat [E, EV] rows (E = router.size)."""
    r = jnp.asarray(router, jnp.int32).reshape(-1)
    p = jnp.broadcast_to(jnp.asarray(port, jnp.int32),
                         jnp.shape(router)).reshape(-1)
    flat = pkt.reshape(-1, pkt.shape[-1])
    w0 = jnp.broadcast_to(jnp.asarray(cycle, jnp.int32), r.shape)
    w1 = r | (p << 16)
    w4 = (pk_dst(flat) | (pk_hops(flat) << 15) | (pk_phase(flat) << 21)
          | (jnp.int32(kind) << 22))
    return jnp.stack([w0, w1, pk_msg(flat), pk_time(flat), w4,
                      pk_inter(flat)], axis=-1)


def ring_append(ts: TraceState, ev, mask) -> TraceState:
    """Append masked event rows to the ring.  Write positions come from
    an exclusive cumsum of the mask, so indices are distinct and the
    single scatter is deterministic; rows past the capacity within one
    call are dropped and counted."""
    buf, n, dropped = ts
    cap = buf.shape[0]
    k = mask.astype(jnp.int32)
    rank = jnp.cumsum(k) - k
    write = mask & (rank < cap)
    idx = jnp.where(write, (n + rank) % cap, cap)       # cap = OOB drop
    buf = buf.at[idx].set(ev, mode="drop")
    wrote = write.sum()
    return TraceState(buf, n + wrote, dropped + (k.sum() - wrote))


def trace_alloc(ts: TraceState, core, cycle, valid, pkt_arr,
                win_net, win_src, ej_net, ej_src, sampler,
                extra=None) -> TraceState:
    """Collect one cycle's events from the allocation outcome: hop
    arrivals (`valid`/`pkt_arr` are the engine's dense per-(router,
    port) arrival view), ejections (granted window slots), plus the
    engine-provided injection events (`extra = (mask, rows)`), as ONE
    ring append."""
    N, P, V, n_ep = core.N, core.P, core.V, core.n_ep
    PKw = win_net.shape[-1]
    evs, masks = [], []
    if extra is not None:
        m_e, ev_e = extra
        evs.append(ev_e)
        masks.append(m_e.reshape(-1))

    routers = jnp.broadcast_to(jnp.arange(N)[:, None], (N, P))
    ports = jnp.broadcast_to(jnp.arange(P)[None, :], (N, P))
    evs.append(pack_events(cycle, KIND_HOP, routers, ports, pkt_arr))
    masks.append((valid & sampler(pkt_arr)).reshape(-1))

    idx_n = jnp.broadcast_to(jnp.maximum(ej_net, 0)[..., None, None],
                             (N, P, V, 1, PKw))
    pkt_n = jnp.take_along_axis(win_net, idx_n, axis=3)[:, :, :, 0, :]
    r3 = jnp.broadcast_to(jnp.arange(N)[:, None, None], (N, P, V))
    p3 = jnp.broadcast_to(jnp.arange(P)[None, :, None], (N, P, V))
    evs.append(pack_events(cycle, KIND_EJECT, r3, p3, pkt_n))
    masks.append(((ej_net >= 0) & sampler(pkt_n)).reshape(-1))

    idx_s = jnp.broadcast_to(jnp.maximum(ej_src, 0)[:, None, None],
                             (n_ep, 1, PKw))
    pkt_s = jnp.take_along_axis(win_src, idx_s, axis=1)[:, 0, :]
    evs.append(pack_events(cycle, KIND_EJECT, core.ep_router,
                           PORT_EP, pkt_s))
    masks.append(((ej_src >= 0) & sampler(pkt_s)).reshape(-1))

    return ring_append(ts, jnp.concatenate(evs),
                       jnp.concatenate(masks))


# ---------------------------------------------------------------------------
# host-side decode
# ---------------------------------------------------------------------------

EVENT_DTYPE = np.dtype([
    ("cycle", np.int32), ("router", np.int32), ("port", np.int32),
    ("msg", np.int32), ("time", np.int32), ("dst", np.int32),
    ("hops", np.int32), ("phase", np.int32), ("kind", np.int32),
    ("inter", np.int32)])


def decode_trace(ts: TraceState):
    """Final TraceState -> (structured event array in chronological
    order, same-cycle overflow drop count).  When the ring wrapped,
    only the most recent `capacity` events survive."""
    buf = np.asarray(ts.buf)
    n, dropped = int(ts.n), int(ts.dropped)
    cap = buf.shape[0]
    if n <= cap:
        rows = buf[:n]
    else:
        s = n % cap
        rows = np.concatenate([buf[s:], buf[:s]])
    ev = np.zeros(len(rows), dtype=EVENT_DTYPE)
    ev["cycle"] = rows[:, 0]
    ev["router"] = rows[:, 1] & 0xFFFF
    ev["port"] = rows[:, 1] >> 16
    ev["msg"] = rows[:, 2]
    ev["time"] = rows[:, 3]
    ev["dst"] = rows[:, 4] & 0x7FFF
    ev["hops"] = (rows[:, 4] >> 15) & 0x3F
    ev["phase"] = (rows[:, 4] >> 21) & 1
    ev["kind"] = rows[:, 4] >> 22
    ev["inter"] = rows[:, 5]
    return ev, dropped


def build_spans(events: np.ndarray) -> list:
    """Group decoded events into per-flit spans.

    A flit is identified by its hop-invariant fields (msg, inject
    cycle, dst, inter) — unique per message in the closed loop and
    per flow in the open loop (module docstring).  Returns dicts
    sorted by that key: ``{msg, dst, phase, start, end, src_router,
    end_router, n_hops, hops: [(cycle, router, port), ...]}`` with
    None for unobserved endpoints (ring overwrite or capacity drop)."""
    spans = {}
    for e in events:
        key = (int(e["msg"]), int(e["time"]), int(e["dst"]),
               int(e["inter"]))
        sp = spans.get(key)
        if sp is None:
            sp = spans[key] = {
                "msg": key[0], "inject_cycle": key[1], "dst": key[2],
                "phase": int(e["phase"]), "start": None, "end": None,
                "src_router": None, "end_router": None, "n_hops": None,
                "hops": []}
        kind = int(e["kind"])
        if kind == KIND_INJECT:
            sp["start"] = int(e["cycle"])
            sp["src_router"] = int(e["router"])
        elif kind == KIND_HOP:
            sp["hops"].append((int(e["cycle"]), int(e["router"]),
                               int(e["port"])))
            sp["phase"] = int(e["phase"])
        else:
            sp["end"] = int(e["cycle"])
            sp["end_router"] = int(e["router"])
            sp["n_hops"] = int(e["hops"])
    for sp in spans.values():
        sp["hops"].sort()
    return [spans[k] for k in sorted(spans)]
