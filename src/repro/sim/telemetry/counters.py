"""Per-router / per-channel counter accumulators (DESIGN.md §12).

Everything here is reconstructed from state the allocation kernel
already computes — no kernel change, no extra gathers on the hot path:

  - `chan_flits[r, o]`: a live output channel forwards exactly one flit
    in the cycles where its winning-request index is set (`win_req[r,o]
    >= 0` ⇔ the downstream (router, port) receives a packet), so the
    per-channel counter is a [N, P] compare-and-add;
  - per-round grant/deny: the kernel grants window slot w in round w
    (`cs_n = where(win_n, w, cs_n)` in `_alloc_rounds_math`), so the
    final grant offsets ARE round indices.  A queue requests in round w
    iff it still holds a packet there and was not granted earlier:
    ``req_w = (count > w) & ((g < 0) | (g >= w))`` with
    ``g = max(chan_slot, ej_slot)``; ``grant_w = (g == w)``; denied =
    requested & ~granted (this includes backpressure/budget blocks, not
    just arbitration losses — that is the congestion signal we want);
  - ejection stats read the granted window slots via one
    take_along_axis over the W axis; endpoint (source-queue) values
    reach their router through the same epr_index gather the engine
    uses for ejection ranking — scatter-free, so the whole layer
    vmaps cleanly over sweep lanes.

Counters are int32.  Worst-case budget (documented, not assumed): the
occupancy sum grows by at most P*V*Qn per router per cycle — at q=25
(P=37, V=4, Qn=16) that is ~2.4k/cycle, overflowing int32 only past
~900k cycles, beyond the closed-loop max_cycles=200k; every other
counter grows by at most P (or p) per cycle.

Conservation identities (asserted by tests/test_telemetry.py):

  sum(chan_flits)  == total hop traversals == sum of pk_hops over
                      delivered flits on a drained run (the src-queue ->
                      first-router traversal counts as a hop on both
                      sides; eject-at-source flits have 0 hops and use
                      no channel);
  sum(ej_count)    == flits delivered;
  sum(alloc_grant) == sum(chan_flits) + sum(ej_count)  (every grant is
                      a channel forward or an ejection).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from ..packed import PK, pk_hops, pk_time

__all__ = ["CounterState", "CountersSnapshot", "init_counters",
           "decode_counters", "count_cycle", "count_routes", "count_alloc"]


class CounterState(NamedTuple):
    """Carry arrays (all int32, all zero-initialised)."""
    chan_flits: jnp.ndarray       # [N, P] flits forwarded per channel
    alloc_grant: jnp.ndarray      # [N, W] grants per allocation round
    alloc_deny: jnp.ndarray       # [N, W] requests denied per round
    route_min: jnp.ndarray        # [n_ep] MIN route choices at injection
    route_val: jnp.ndarray        # [n_ep] VAL/non-minimal choices
    occ_sum: jnp.ndarray          # [N] sum over cycles of queued flits
    occ_max: jnp.ndarray          # [N] max per-(port,VC) queue depth seen
    ej_count: jnp.ndarray         # [N] flits ejected at this router
    ej_lat_sum: jnp.ndarray       # [N] sum of ejected-flit latencies
    ej_lat_max: jnp.ndarray       # [N] max ejected-flit latency
    ej_hops_sum: jnp.ndarray      # [N] sum of ejected-flit hop counts


def init_counters(core) -> CounterState:
    N, P, W, n_ep = core.N, core.P, core.W, core.n_ep
    z = lambda *shape: jnp.zeros(shape, jnp.int32)
    return CounterState(
        chan_flits=z(N, P), alloc_grant=z(N, W), alloc_deny=z(N, W),
        route_min=z(n_ep), route_val=z(n_ep),
        occ_sum=z(N), occ_max=z(N),
        ej_count=z(N), ej_lat_sum=z(N), ej_lat_max=z(N),
        ej_hops_sum=z(N))


def _ep_to_router(core, vals, reduce: str = "sum"):
    """Per-endpoint values -> per-router totals, scatter-free: endpoints
    are sorted by router with exactly p per endpoint-router, so a block
    reduce + the epr_index gather routes them (same trick as the
    engine's ejection ranking; non-endpoint routers contribute 0)."""
    blocks = vals.reshape(core.n_epr, core.p)
    agg = blocks.sum(axis=1) if reduce == "sum" else blocks.max(axis=1)
    g = agg[jnp.maximum(core.epr_index, 0)]
    return jnp.where(core.epr_index >= 0, g, 0)


def count_cycle(cs: CounterState, nq_count) -> CounterState:
    """Cycle-start queue-occupancy accumulation (network queues)."""
    return cs._replace(
        occ_sum=cs.occ_sum + nq_count.sum(axis=(1, 2)),
        occ_max=jnp.maximum(cs.occ_max, nq_count.max(axis=(1, 2))))


def count_routes(cs: CounterState, want, phase) -> CounterState:
    """Injection-time route-choice counts: phase 1 = MIN, 0 = VAL
    (route_decision's convention; `want` masks actual injections)."""
    w = want.astype(jnp.int32)
    return cs._replace(route_min=cs.route_min + w * (phase == 1),
                       route_val=cs.route_val + w * (phase != 1))


def count_alloc(cs: CounterState, core, cycle, win_net, win_src, win_req,
                chan_net, ej_net, chan_src, ej_src,
                cnt_net, sq_count) -> CounterState:
    """Per-cycle counter update from the allocation outcome.

    Called by SwitchCore.alloc with cycle-START queue counts
    (`cnt_net` is the live-masked [N, P*V] depth array the kernel saw,
    `sq_count` the per-endpoint source depths) and the final grant
    offsets split by kind (`chan_*` / `ej_*`, -1 = no grant).
    """
    N, P, V, W = core.N, core.P, core.V, core.W
    i32 = jnp.int32
    n_ep = core.n_ep

    chan_flits = cs.chan_flits + ((win_req >= 0)
                                  & (core.nbr >= 0)).astype(i32)

    # ---- per-round grant/deny reconstruction (module docstring)
    g_net = jnp.maximum(chan_net, ej_net)                  # [N, P, V]
    g_src = jnp.maximum(chan_src, ej_src)                  # [n_ep]
    cnt3 = cnt_net.reshape(N, P, V)
    grants, denies = [], []
    for w in range(W):
        req_n = (cnt3 > w) & ((g_net < 0) | (g_net >= w))
        req_s = (sq_count > w) & ((g_src < 0) | (g_src >= w))
        gr_n, gr_s = g_net == w, g_src == w
        grants.append(gr_n.sum(axis=(1, 2))
                      + _ep_to_router(core, gr_s.astype(i32)))
        denies.append((req_n & ~gr_n).sum(axis=(1, 2))
                      + _ep_to_router(core, (req_s & ~gr_s).astype(i32)))
    alloc_grant = cs.alloc_grant + jnp.stack(grants, axis=1)
    alloc_deny = cs.alloc_deny + jnp.stack(denies, axis=1)

    # ---- ejection stats from the granted window slots (the ejecting
    # router IS the destination router)
    idx_n = jnp.broadcast_to(jnp.maximum(ej_net, 0)[..., None, None],
                             (N, P, V, 1, PK))
    pkt_n = jnp.take_along_axis(win_net, idx_n, axis=3)[:, :, :, 0, :]
    m_n = ej_net >= 0
    lat_n = jnp.where(m_n, cycle - pk_time(pkt_n) + 1, 0)
    hop_n = jnp.where(m_n, pk_hops(pkt_n), 0)

    idx_s = jnp.broadcast_to(jnp.maximum(ej_src, 0)[:, None, None],
                             (n_ep, 1, PK))
    pkt_s = jnp.take_along_axis(win_src, idx_s, axis=1)[:, 0, :]
    m_s = ej_src >= 0
    lat_s = jnp.where(m_s, cycle - pk_time(pkt_s) + 1, 0)
    hop_s = jnp.where(m_s, pk_hops(pkt_s), 0)

    ej_count = (cs.ej_count + m_n.sum(axis=(1, 2))
                + _ep_to_router(core, m_s.astype(i32)))
    ej_lat_sum = (cs.ej_lat_sum + lat_n.sum(axis=(1, 2))
                  + _ep_to_router(core, lat_s))
    ej_hops_sum = (cs.ej_hops_sum + hop_n.sum(axis=(1, 2))
                   + _ep_to_router(core, hop_s))
    ej_lat_max = jnp.maximum(
        cs.ej_lat_max,
        jnp.maximum(lat_n.max(axis=(1, 2)),
                    _ep_to_router(core, lat_s, reduce="max")))

    return cs._replace(
        chan_flits=chan_flits, alloc_grant=alloc_grant,
        alloc_deny=alloc_deny, ej_count=ej_count, ej_lat_sum=ej_lat_sum,
        ej_lat_max=ej_lat_max, ej_hops_sum=ej_hops_sum)


# ---------------------------------------------------------------------------
# host-side decode
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CountersSnapshot:
    """Host (numpy, int64) view of a run's final CounterState."""
    cycles: int
    chan_flits: np.ndarray        # [N, P]
    alloc_grant: np.ndarray       # [N, W]
    alloc_deny: np.ndarray        # [N, W]
    route_min: np.ndarray         # [n_ep]
    route_val: np.ndarray         # [n_ep]
    occ_sum: np.ndarray           # [N]
    occ_max: np.ndarray           # [N]
    ej_count: np.ndarray          # [N]
    ej_lat_sum: np.ndarray        # [N]
    ej_lat_max: np.ndarray        # [N]
    ej_hops_sum: np.ndarray       # [N]

    def channel_load(self) -> np.ndarray:
        """Per-channel utilisation: flits forwarded / cycle in [0, 1]."""
        return self.chan_flits / max(self.cycles, 1)

    def deny_rate(self) -> np.ndarray:
        """Per-router fraction of queue-requests denied per cycle."""
        g = self.alloc_grant.sum(axis=1)
        d = self.alloc_deny.sum(axis=1)
        return d / np.maximum(g + d, 1)

    def mean_queue_occupancy(self) -> np.ndarray:
        """Per-router mean total network-queue depth (flits)."""
        return self.occ_sum / max(self.cycles, 1)

    def mean_ej_latency(self) -> np.ndarray:
        """Per-destination-router mean flit latency (nan = no flits)."""
        with np.errstate(invalid="ignore"):
            return np.where(self.ej_count > 0,
                            self.ej_lat_sum / np.maximum(self.ej_count, 1),
                            np.nan)


def decode_counters(cs: CounterState, cycles: int) -> CountersSnapshot:
    f = [np.asarray(a, dtype=np.int64) for a in cs]
    return CountersSnapshot(int(cycles), *f)
