"""Traffic patterns of paper §V.

Each pattern is a `Traffic` with:
  - active:     bool [N_ep] — endpoints that inject/receive
  - sample(key) -> int32 [N_ep] destination endpoint per source
Deterministic patterns ignore the key.  Bit-permutation patterns activate
the largest power-of-two subset of endpoints (paper §V-B: 8192 of ~10K).

Lane contract (DESIGN.md §10): `sample` must be a pure jax function of
its key — the sweep engine vmaps it over per-lane keys, so stochastic
patterns draw an independent stream per lane while deterministic
patterns broadcast.  The injection RATE is not traffic state at all
(it is a traced operand of the engine), which is what lets one
compiled Traffic serve every lane of a load sweep.  A pattern derived
from a specific table set (`worstcase_sf`) is shared across failure
lanes: the adversarial pairing is fixed on the healthy fabric and the
lanes measure how each mask degrades it.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .tables import SimTables

__all__ = ["Traffic", "make_traffic"]


@dataclasses.dataclass
class Traffic:
    name: str
    active: np.ndarray                      # bool [N_ep]
    sample: Callable                        # key -> [N_ep] dst endpoint


def _perm_traffic(name: str, dst_of: np.ndarray, active: np.ndarray) -> Traffic:
    dst = jnp.asarray(dst_of, dtype=jnp.int32)
    return Traffic(name=name, active=active, sample=lambda key: dst)


def make_traffic(tables: SimTables, pattern: str, seed: int = 0) -> Traffic:
    n_ep = tables.n_endpoints
    ids = np.arange(n_ep)

    if pattern == "uniform":
        active = np.ones(n_ep, dtype=bool)

        def sample(key):
            # uniform over OTHER endpoints
            d = jax.random.randint(key, (n_ep,), 0, n_ep - 1)
            return jnp.where(d >= jnp.arange(n_ep), d + 1, d).astype(jnp.int32)

        return Traffic("uniform", active, sample)

    if pattern in ("shuffle", "bitrev", "bitcomp"):
        b = int(np.floor(np.log2(n_ep)))
        n_act = 1 << b
        active = ids < n_act
        s = ids[:n_act]
        if pattern == "shuffle":        # d_i = s_{i-1 mod b}: rotate left
            d = ((s << 1) | (s >> (b - 1))) & (n_act - 1)
        elif pattern == "bitrev":
            d = np.zeros_like(s)
            for i in range(b):
                d |= ((s >> i) & 1) << (b - 1 - i)
        else:                            # bit complement
            d = (~s) & (n_act - 1)
        dst_of = np.concatenate([d, ids[n_act:]])   # inactive: self (unused)
        return _perm_traffic(pattern, dst_of, active)

    if pattern == "shift":
        b = int(np.floor(np.log2(n_ep)))
        n_act = 1 << b
        active = ids < n_act
        half = n_act // 2

        def sample(key):
            coin = jax.random.bernoulli(key, 0.5, (n_ep,))
            base = jnp.arange(n_ep) % half
            return jnp.where(coin, base + half, base).astype(jnp.int32)

        return Traffic("shift", active, sample)

    if pattern == "worstcase_sf":
        return _worstcase_sf(tables, seed)

    if pattern == "worstcase_df":
        return _worstcase_df(tables)

    raise ValueError(f"unknown traffic pattern {pattern!r}")


def _worstcase_sf(tables: SimTables, seed: int = 0) -> Traffic:
    """§V-C: maximal load on one link (Rx -> Ry).

    A = routers whose 2-hop MIN path to Rx goes via Ry  (their endpoints
        send to Rx's endpoints),
    B = routers whose 2-hop MIN path to Ry goes via Rx  (send to Ry's),
    and Rx's endpoints send back to A's, Ry's to B's ("send and receive").
    `seed` drives the candidate-link sampling (the link search is
    sampled, not exhaustive, on large networks).
    """
    dist, pt, nbr = tables.dist, tables.port_toward, tables.nbr
    n = tables.n_routers
    p = tables.p
    ep_router = tables.ep_router
    n_ep = tables.n_endpoints

    # choose the link maximising |A| + |B|
    best, best_ab = None, -1
    rng = np.random.default_rng(seed)
    cand_links = [(rx, int(v)) for rx in rng.choice(n, size=min(n, 64),
                                                    replace=False)
                  for v in nbr[rx][nbr[rx] >= 0][:8]]
    nh = np.full((n, n), -1, dtype=np.int64)
    valid = pt >= 0
    nh[valid] = nbr[np.nonzero(valid)[0], pt[valid]]
    for rx, ry in cand_links:
        A = np.nonzero((dist[:, rx] == 2) & (nh[:, rx] == ry))[0]
        B = np.nonzero((dist[:, ry] == 2) & (nh[:, ry] == rx))[0]
        if len(A) + len(B) > best_ab:
            best_ab = len(A) + len(B)
            best = (rx, ry, A, B)
    rx, ry, A, B = best

    eps_of = lambda r: np.nonzero(ep_router == r)[0]
    dst_of = np.arange(n_ep)
    active = np.zeros(n_ep, dtype=bool)

    def assign(src_routers, dst_router):
        d_eps = eps_of(dst_router)
        src_eps = np.concatenate([eps_of(r) for r in src_routers]) \
            if len(src_routers) else np.array([], dtype=np.int64)
        if len(src_eps) == 0:
            return src_eps
        dst_of[src_eps] = d_eps[np.arange(len(src_eps)) % len(d_eps)]
        active[src_eps] = True
        return src_eps

    a_eps = assign(A, rx)
    b_eps = assign(B, ry)
    # reverse direction: Rx's endpoints -> A's endpoints, Ry's -> B's
    for r_c, eps_back in ((rx, a_eps), (ry, b_eps)):
        src = eps_of(r_c)
        if len(eps_back):
            dst_of[src] = eps_back[np.arange(len(src)) % len(eps_back)]
            active[src] = True

    return _perm_traffic("worstcase_sf", dst_of, active)


def _worstcase_df(tables: SimTables) -> Traffic:
    """Kim et al. §4.2 adversarial: every endpoint of group g sends to a
    random endpoint of group g+1, overloading one global channel/group."""
    topo = tables.topo
    a = topo.params["a"]
    g = topo.params["g"]
    p = tables.p
    n_ep = tables.n_endpoints
    grp_of_ep = (np.arange(n_ep) // p) // a
    eps_per_grp = a * p

    def sample(key):
        tgt_grp = (grp_of_ep + 1) % g
        off = jax.random.randint(key, (n_ep,), 0, eps_per_grp)
        return (tgt_grp * eps_per_grp + off).astype(jnp.int32)

    return Traffic("worstcase_df", np.ones(n_ep, dtype=bool), sample)
