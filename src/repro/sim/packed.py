"""Bit-packed flit records (DESIGN.md §9).

Packet state dominates the simulator's memory traffic: at paper scale
(SF q=17, N=578 routers, k'=25, 4 VCs) the network queue array holds
N * P * V * Qn ~ 925k flit slots, and every cycle gathers a W-slot
window of it and scatters arrivals/compactions back.  The seed engine
stored each record as 5 (open loop) or 6 (closed loop) int32 fields;
here every record is exactly ``PK = 3`` int32 words regardless of
engine:

  word 0   dst_router | inter_router << 16   (15 bits each)
  word 1   inject_cycle                      (full int32)
  word 2   hops | phase << 6 | msg << 7      (6 / 1 / 24 bits)

Field budget (asserted, not assumed):

  - router ids need N < 2**15; the largest Slim Fly we target
    (q = 25) has N = 1250 routers, and every comparison topology in
    the repo stays far below 32768;
  - hops saturate at ``HOPS_MAX`` = 63.  The engine only ever consumes
    ``min(hops, V-1)`` (hop-indexed VC assignment), so saturation is
    observationally equivalent to the seed's unbounded counter;
  - msg ids (closed-loop DAG messages) need M < 2**24 (~16.7M — the
    largest workload in the repo is a few thousand messages).  The
    multi-job engine (repro.sim.workloads.jobs) splits the same 24-bit
    budget into ``job << MSG_JOB_SHIFT | local_msg``: 6 job bits
    (MAX_JOBS = 64 concurrent jobs) over 18 local-message bits
    (MAX_JOB_MSGS = 262144 messages per job).  Job 0 with local ids is
    numerically identical to the unsplit field, so single-job runs
    produce bit-identical records;
  - inject_cycle keeps a full int32 word: closed-loop runs go to
    max_cycles = 200k and latency sums must not wrap (the int16-ish
    packing an earlier draft used would wrap at cycle 32768).

Hot paths (ejection folds, route desires) read fields through the
``pk_*`` accessors directly — no unpack boundary sits on the engine's
per-cycle path.  `unpack_record`, which restores the seed's flat int32
record ``(dst, inter, time, hops, phase[, msg])``, exists for tests
and debugging.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "PK", "HOPS_MAX", "MAX_ROUTERS", "MAX_MSGS",
    "MSG_JOB_SHIFT", "MAX_JOBS", "MAX_JOB_MSGS",
    "pack_record", "unpack_record", "bump_hops_word",
    "pk_dst", "pk_inter", "pk_time", "pk_hops", "pk_phase", "pk_msg",
    "pk_flow_key", "pk_job", "pk_job_mid",
]

PK = 3                      # int32 words per packed record
HOPS_MAX = 63               # saturating hop counter (6 bits)
MAX_ROUTERS = 1 << 15       # router ids must fit 15 bits
MAX_MSGS = 1 << 24          # closed-loop msg ids must fit 24 bits

# multi-job split of the 24-bit MSG field: job id in the high 6 bits,
# per-job local message id in the low 18 (job 0 == unsplit field, so
# the single-job engine's records are unchanged bit-for-bit)
MSG_JOB_SHIFT = 18
MAX_JOBS = 1 << (24 - MSG_JOB_SHIFT)        # 64 concurrent jobs
MAX_JOB_MSGS = 1 << MSG_JOB_SHIFT           # 262144 messages per job

_HOPS_MASK = jnp.int32(HOPS_MAX)
_ID_MASK = jnp.int32(0xFFFF)
_JOB_MID_MASK = jnp.int32(MAX_JOB_MSGS - 1)


def pack_record(dst, inter, time, hops, phase, msg=None):
    """Stack fields into a packed [..., PK] int32 record."""
    dst = jnp.asarray(dst, jnp.int32)
    inter = jnp.asarray(inter, jnp.int32)
    w0 = dst | (jnp.asarray(inter, jnp.int32) << 16)
    w2 = (jnp.asarray(hops, jnp.int32)
          | (jnp.asarray(phase, jnp.int32) << 6))
    if msg is not None:
        w2 = w2 | (jnp.asarray(msg, jnp.int32) << 7)
    w1 = jnp.broadcast_to(jnp.asarray(time, jnp.int32), dst.shape)
    w2 = jnp.broadcast_to(w2, dst.shape)
    return jnp.stack([w0, w1, w2], axis=-1)


def pk_dst(pkt):
    return pkt[..., 0] & _ID_MASK


def pk_inter(pkt):
    # word 0 is non-negative (ids < 2**15), so the arithmetic shift is
    # an exact field extract
    return pkt[..., 0] >> 16


def pk_time(pkt):
    return pkt[..., 1]


def pk_hops(pkt):
    return pkt[..., 2] & _HOPS_MASK


def pk_phase(pkt):
    return (pkt[..., 2] >> 6) & 1


def pk_msg(pkt):
    return pkt[..., 2] >> 7


def pk_flow_key(pkt):
    """Hop-invariant identity of a packet: (word 0, word 1).  Word 0
    (dst | inter << 16) and word 1 (inject cycle) are fixed for a
    flit's whole lifetime (`bump_hops_word` only touches word 2), so
    telemetry's open-loop trace sampler can hash them at every hop and
    get the same answer."""
    return pkt[..., 0], pkt[..., 1]


def pk_job(pkt):
    """Job id bits of the MSG field (0 for single-job records)."""
    return pk_msg(pkt) >> MSG_JOB_SHIFT


def pk_job_mid(pkt):
    """Per-job local message id bits of the MSG field."""
    return pk_msg(pkt) & _JOB_MID_MASK


def bump_hops_word(w2, set_phase):
    """word-2 update on link traversal: hops+1 (saturating at HOPS_MAX),
    phase |= set_phase; msg bits carried through untouched."""
    hops = jnp.minimum((w2 & _HOPS_MASK) + 1, _HOPS_MASK)
    phase = ((w2 >> 6) & 1) | jnp.asarray(set_phase, jnp.int32)
    rest = (w2 >> 7) << 7
    return rest | hops | (phase << 6)


def unpack_record(pkt, n_fields: int):
    """Packed [..., PK] -> flat int32 [..., n_fields] seed-layout record
    (dst, inter, time, hops, phase[, msg])."""
    fields = [pk_dst(pkt), pk_inter(pkt), pk_time(pkt), pk_hops(pkt),
              pk_phase(pkt)]
    if n_fields == 6:
        fields.append(pk_msg(pkt))
    return jnp.stack(fields, axis=-1)
