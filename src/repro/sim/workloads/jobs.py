"""Multi-tenant job scheduling on a shared fabric (DESIGN.md §11).

A :class:`Job` wraps an existing message-DAG :class:`Workload` (whose
phases are the Job's phases) with an arrival cycle; `run_jobs` places
each job's ranks on endpoints (`pack` / `spread` / `rack-aware`
policies, all built on `place_ranks`), admits jobs through a FIFO or
backfill queue when their endpoints are busy, and runs the whole mix
as ONE closed-loop simulation on the concatenated message space of
`repro.sim.workloads.closed_loop` — so co-located jobs contend for
real links, buffers and allocator grants, which is the interference
the multitenant benchmark measures (SF vs DF vs FT-3 at equal cost,
cf. Blach et al., arXiv:2310.03742).

Semantics (also DESIGN.md §11):

  - Placement is decided once, host-side, in arrival order: each
    policy defines a total endpoint order (a `place_ranks` scheme over
    ALL endpoints) and jobs take consecutive slices of it; rack-aware
    additionally aligns each job's slice to the next rack boundary.
    When cumulative demand exceeds the fabric the slice wraps modulo
    n_endpoints — the wrapped job overlaps earlier ones and the
    admission queue serialises it.
  - Admission is evaluated at chunk boundaries (granularity =
    cfg.chunk, like the engine's early exit).  A job admitted while
    its endpoints are free starts injecting exactly at
    max(arrival, boundary); jobs whose endpoints overlap a running
    job wait — `fifo` blocks everything behind the head of the queue,
    `backfill` admits any waiting job whose endpoints are free.
  - Inside the compiled step the only job-level state is the per-job
    admit-cycle vector (carried, data-only), so the lane sweep's
    shape-static contract holds: the job mix and placement are traced,
    admission cycles are operands.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...core.layout import make_layout
from .. import telemetry as tel
from ..engine import BIG
from ..tables import SimTables
from ..telemetry import TelemetrySnapshot
from .closed_loop import WorkloadSimConfig, _space_runner
from .ir import Workload
from .mapping import place_ranks

__all__ = ["Job", "JobResult", "MultiJobResult", "JOB_PLACEMENTS",
           "QUEUE_POLICIES", "ARRIVALS", "place_jobs", "run_jobs",
           "poisson_arrivals", "with_arrivals"]

JOB_PLACEMENTS = ("pack", "spread", "rack-aware")
QUEUE_POLICIES = ("fifo", "backfill")
ARRIVALS = ("fixed", "poisson")

# job placement policy -> the place_ranks scheme whose full-fabric
# permutation defines the allocation order
_ORDER_SCHEME = {"pack": "linear", "spread": "spread",
                 "rack-aware": "blocked"}


@dataclasses.dataclass(frozen=True)
class Job:
    """One tenant: a message-DAG workload arriving at a given cycle."""
    name: str
    workload: Workload
    arrival: int = 0

    @property
    def n_ranks(self) -> int:
        return self.workload.n_ranks

    @property
    def n_messages(self) -> int:
        return self.workload.n_messages


@dataclasses.dataclass
class JobResult:
    name: str
    arrival: int
    admit_cycle: int                  # -1 if never admitted
    completed: bool
    start: int                        # first flit injection (-1 never)
    done: int                         # completion cycle (-1 never)
    n_ranks: int
    n_messages: int
    flits_delivered: int
    msg_start: np.ndarray             # [Mj] first-injection cycle
    msg_done: np.ndarray              # [Mj] completion cycle
    msg_size: np.ndarray              # [Mj]
    msg_phase: np.ndarray             # [Mj]
    ep_of_rank: np.ndarray            # [n_ranks]

    @property
    def jct(self) -> float:
        """Job completion time: arrival -> done (includes queueing)."""
        return float(self.done - self.arrival) if self.completed \
            else float("inf")

    @property
    def queue_delay(self) -> int:
        """Cycles spent waiting for endpoints (admit - arrival)."""
        return max(0, self.admit_cycle - self.arrival) \
            if self.admit_cycle >= 0 else -1

    def latencies(self) -> np.ndarray:
        """Per-message start->done latencies over completed messages."""
        ok = self.msg_done >= 0
        return (self.msg_done[ok] - self.msg_start[ok]).astype(np.float64)


@dataclasses.dataclass
class MultiJobResult:
    jobs: Tuple[JobResult, ...]
    policy: str
    queue: str
    mode: str
    completed: bool                   # every job drained its DAG
    cycles_run: int
    makespan: float                   # last job completion; inf if not
    flits_delivered: int
    per_cycle_delivered: np.ndarray   # [cycles_run]
    telemetry: Optional[TelemetrySnapshot] = None

    def job(self, name: str) -> JobResult:
        for jr in self.jobs:
            if jr.name == name:
                return jr
        raise KeyError(name)


def poisson_arrivals(n_jobs: int, rate: float, seed: int = 0,
                     start: int = 0) -> np.ndarray:
    """Sample `n_jobs` arrival CYCLES from a Poisson process of `rate`
    jobs/cycle (i.i.d. exponential inter-arrival gaps, floored to
    integer cycles — ROADMAP "stochastic arrival processes").

    The samples feed `Job.arrival` host-side only: admission stays a
    data-only admit-cycle vector inside the compiled step, so a rate
    or seed sweep reuses one executable (DESIGN.md §10/§11).
    """
    assert n_jobs >= 1 and rate > 0
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_jobs)
    return (start + np.floor(np.cumsum(gaps))).astype(np.int64)


def with_arrivals(jobs: Sequence[Job], arrivals: str = "poisson",
                  rate: float = 1e-3, seed: int = 0,
                  offsets: Optional[Sequence[int]] = None) -> Tuple[Job, ...]:
    """Return `jobs` restamped with sampled (or fixed) arrival cycles,
    sorted by arrival — ready for `run_jobs` (whose list order is the
    FIFO order).

    arrivals="poisson": cycles from `poisson_arrivals(len(jobs), rate,
    seed)`, assigned in list order.  arrivals="fixed": `offsets`
    verbatim (defaults to each job's existing arrival).
    """
    jobs = tuple(jobs)
    if arrivals not in ARRIVALS:
        raise ValueError(f"unknown arrivals {arrivals!r}; have {ARRIVALS}")
    if arrivals == "poisson":
        cycles = poisson_arrivals(len(jobs), rate, seed)
    else:
        cycles = np.asarray([j.arrival for j in jobs] if offsets is None
                            else list(offsets), dtype=np.int64)
        assert cycles.shape == (len(jobs),)
    stamped = [dataclasses.replace(j, arrival=int(c))
               for j, c in zip(jobs, cycles)]
    return tuple(sorted(stamped, key=lambda j: j.arrival))


def place_jobs(tables: SimTables, jobs: Sequence[Job],
               policy: str = "pack") -> List[np.ndarray]:
    """Slice the policy's endpoint order into per-job placements, in
    arrival (list) order.  Returns ep_of_rank arrays, one per job."""
    if policy not in JOB_PLACEMENTS:
        raise ValueError(
            f"unknown job placement {policy!r}; have {JOB_PLACEMENTS}")
    n_ep = tables.n_endpoints
    order = place_ranks(tables, n_ep, _ORDER_SCHEME[policy])
    rack_seq = None
    if policy == "rack-aware":
        layout = make_layout(tables.topo)
        rack_seq = layout.rack_of[tables.ep_router[order]]

    placements = []
    cursor = 0
    for job in jobs:
        k = job.n_ranks
        if k > n_ep:
            raise ValueError(
                f"job {job.name!r}: {k} ranks > {n_ep} endpoints")
        if rack_seq is not None and 0 < cursor < n_ep and \
                rack_seq[cursor] == rack_seq[cursor - 1]:
            # rack-aware: start each job on a fresh rack so tenants
            # don't share rack-local links
            nxt = cursor
            while nxt < n_ep and rack_seq[nxt] == rack_seq[cursor - 1]:
                nxt += 1
            cursor = nxt % n_ep
        idx = (cursor + np.arange(k)) % n_ep
        placements.append(order[idx].astype(np.int32))
        cursor = (cursor + k) % n_ep
    return placements


def _admit_pass(jobs: Sequence[Job], placements: Sequence[np.ndarray],
                n_ep: int, admit: np.ndarray, done: np.ndarray,
                t: int, queue: str) -> np.ndarray:
    """One admission-queue evaluation at boundary cycle `t`.

    A job's endpoints are reserved from admission until completion.
    Pending jobs are scanned in arrival (list) order; `fifo` stops at
    the first job that doesn't fit, `backfill` keeps scanning.
    """
    admit = admit.copy()
    busy = np.zeros(n_ep, dtype=bool)
    for j in range(len(jobs)):
        if admit[j] < BIG and not done[j]:
            busy[placements[j]] = True
    for j in range(len(jobs)):
        if admit[j] < BIG:
            continue
        if not busy[placements[j]].any():
            admit[j] = max(jobs[j].arrival, t)
            busy[placements[j]] = True
        elif queue == "fifo":
            break
    return admit


def run_jobs(tables: SimTables, jobs: Sequence[Job],
             cfg: WorkloadSimConfig = WorkloadSimConfig(),
             policy: str = "pack", queue: str = "fifo",
             placements: Optional[Sequence[np.ndarray]] = None
             ) -> MultiJobResult:
    """Run a job mix to completion (or cfg.max_cycles) on one fabric.

    `jobs` must be sorted by arrival cycle — list order IS the FIFO
    order.  One compiled chunk runner covers the whole mix; between
    chunks the host-side admission queue turns completions into new
    admit cycles (see module docstring for the exact semantics).
    """
    jobs = tuple(jobs)
    if not jobs:
        raise ValueError("empty job list")
    if queue not in QUEUE_POLICIES:
        raise ValueError(f"unknown queue {queue!r}; have {QUEUE_POLICIES}")
    arrivals = [j.arrival for j in jobs]
    if arrivals != sorted(arrivals):
        raise ValueError("jobs must be sorted by arrival cycle "
                         "(list order is the FIFO order)")

    if placements is None:
        placements = place_jobs(tables, jobs, policy)
    placements = [np.asarray(p, dtype=np.int32) for p in placements]
    assert len(placements) == len(jobs)

    wls = tuple(j.workload for j in jobs)
    run_chunk, init_carry, _, space = _space_runner(
        tables, wls, tuple(placements), cfg)

    J = len(jobs)
    big = int(BIG)
    msgs_per_job = np.diff(space.job_off)
    admit = np.full(J, big, dtype=np.int64)
    done = np.zeros(J, dtype=bool)
    admit = _admit_pass(jobs, placements, tables.n_endpoints,
                        admit, done, 0, queue)

    carry = init_carry(jax.random.PRNGKey(cfg.seed),
                       jnp.asarray(admit.astype(np.int32)))
    per_cycle_dlv = []
    completed = False
    t = 0
    while t < cfg.max_cycles:
        carry, (inj, dlv, n_done) = run_chunk(carry, jnp.int32(t))
        per_cycle_dlv.append(np.asarray(dlv, dtype=np.int64))
        t += cfg.chunk
        done = np.asarray(n_done)[-1] == msgs_per_job
        if done.all():
            completed = True
            break
        new_admit = _admit_pass(jobs, placements, tables.n_endpoints,
                                admit, done, t, queue)
        if (new_admit != admit).any():
            admit = new_admit
            carry = carry[:4] + (jnp.asarray(admit.astype(np.int32)),) \
                + carry[5:]

    (_, _, _, _, _, sent, flits_del, start_c, done_c, _, ts) = carry
    start_c = np.asarray(start_c, dtype=np.int64)
    done_c = np.asarray(done_c, dtype=np.int64)
    flits_del = np.asarray(flits_del, dtype=np.int64)
    per_cycle = np.concatenate(per_cycle_dlv)

    job_results = []
    for j, job in enumerate(jobs):
        s, e = int(space.job_off[j]), int(space.job_off[j + 1])
        js, jd = start_c[s:e], done_c[s:e]
        jcomp = bool(done[j])
        job_results.append(JobResult(
            name=job.name, arrival=job.arrival,
            admit_cycle=int(admit[j]) if admit[j] < big else -1,
            completed=jcomp,
            start=int(js.min()) if (js < big).any() else -1,
            done=int(jd.max()) if jcomp else -1,
            n_ranks=job.n_ranks, n_messages=job.n_messages,
            flits_delivered=int(flits_del[s:e].sum()),
            msg_start=np.where(js < big, js, -1),
            msg_done=np.where(jd < big, jd, -1),
            msg_size=job.workload.size.copy(),
            msg_phase=job.workload.phase.copy(),
            ep_of_rank=placements[j]))

    makespan = (float(max(jr.done for jr in job_results)) if completed
                else float("inf"))
    cycles_run = t
    if completed:
        # same trimming as the single-workload path: the chunked loop
        # overshoots completion to the chunk boundary
        cycles_run = int(makespan)
        per_cycle = per_cycle[:cycles_run]

    return MultiJobResult(
        jobs=tuple(job_results), policy=policy, queue=queue,
        mode=cfg.mode, completed=completed, cycles_run=cycles_run,
        makespan=makespan, flits_delivered=int(flits_del.sum()),
        per_cycle_delivered=per_cycle,
        telemetry=tel.snapshot(cfg.telemetry, ts, cycles_run))
