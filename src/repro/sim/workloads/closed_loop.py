"""Closed-loop dependency-triggered workload engine (DESIGN.md §7).

Runs a :class:`~repro.sim.workloads.ir.Workload` message-DAG to
completion on the cycle-level flit simulator and measures job
completion time — the quantity the open-loop Bernoulli engine
(`repro.sim.engine.simulate`) structurally cannot produce.

The engine shares :class:`repro.sim.engine.SwitchCore` (credit view,
route choice, W-round allocation, compaction) with the open-loop
simulator; only injection and the ejection fold differ:

  - packet records carry an extra MSG field (bit-packed, see
    repro.sim.packed) naming the message a flit belongs to, so the
    ejection fold can scatter-add per-message delivered-flit counts;
  - each cycle the ready set is re-derived as a dense mask over DAG
    messages from the carried delivered-flit counters (`done[dep]`
    gather over the padded dep matrix), every endpoint injects one flit
    of its lowest-id ready unfinished message, and a message completes
    when its delivered count reaches its size;
  - the scan runs in fixed-size compiled chunks with a host-side
    all-done check between chunks: one trace/compile per (tables,
    workload, placement, config) signature regardless of makespan, and
    early exit at chunk granularity.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..engine import (BIG, SimConfig, SwitchCore, _cache_put,
                      tables_signature)
from ..packed import MAX_MSGS, pack_record, pk_msg
from ..tables import SimTables
from .ir import Workload
from .mapping import place_ranks

__all__ = ["WorkloadSimConfig", "WorkloadResult", "run_workload"]


@dataclasses.dataclass(frozen=True)
class WorkloadSimConfig:
    vcs: int = 4
    q_net: int = 16
    q_src: int = 64
    mode: str = "min"                 # min | val | ugal_l | ugal_g | ecmp
    n_val_candidates: int = 4
    lookahead: int = 4
    seed: int = 0
    placement: str = "linear"         # see workloads.mapping.PLACEMENTS
    chunk: int = 256                  # cycles per compiled scan chunk
    max_cycles: int = 200_000         # give up (makespan = inf) past this
    kernel_path: str = "auto"         # auto | ref | pallas (DESIGN.md §9)

    def to_sim_config(self) -> SimConfig:
        return SimConfig(vcs=self.vcs, q_net=self.q_net, q_src=self.q_src,
                         mode=self.mode,
                         n_val_candidates=self.n_val_candidates,
                         lookahead=self.lookahead, seed=self.seed,
                         kernel_path=self.kernel_path)

    def static_key(self) -> tuple:
        return (self.vcs, self.q_net, self.q_src, self.mode,
                self.n_val_candidates, self.lookahead, self.placement,
                self.chunk, self.kernel_path)


@dataclasses.dataclass
class WorkloadResult:
    name: str
    mode: str
    placement: str
    n_ranks: int
    n_messages: int
    completed: bool
    makespan: float                   # cycles; inf if hit max_cycles
    cycles_run: int
    flits_injected: int
    flits_delivered: int
    msg_size: np.ndarray              # [M]
    msg_phase: np.ndarray             # [M]
    msg_sent: np.ndarray              # [M] flits injected per message
    msg_delivered: np.ndarray         # [M] flits ejected per message
    msg_start: np.ndarray             # [M] first-injection cycle (-1 never)
    msg_done: np.ndarray              # [M] completion cycle (-1 never)
    per_cycle_delivered: np.ndarray   # [cycles_run]
    ep_of_rank: np.ndarray            # [n_ranks] the placement used

    @property
    def achieved_bw(self) -> float:
        """Delivered flits per cycle over the makespan (fabric-level)."""
        if not np.isfinite(self.makespan) or self.makespan <= 0:
            return 0.0
        return float(self.flits_delivered / self.makespan)

    @property
    def avg_msg_latency(self) -> float:
        """Mean message start->completion time, completed messages."""
        ok = self.msg_done >= 0
        if not ok.any():
            return float("nan")
        return float((self.msg_done[ok] - self.msg_start[ok]).mean())


# (tables, workload, placement-bytes, static-config) -> compiled chunk
# runner.  The single-lane runner keeps the tables as closure constants
# (gather specialisation, see repro.sim.engine) and so recompiles per
# failure mask; the lane-batched sweep below lifts them into operands
# so all masks of one topology share one executable (DESIGN.md §10).
# Values pin the keyed objects against id() reuse, and the shared FIFO
# bound caps compiled-executable retention.
_RUNNER_CACHE: dict = {}


def _chunk_runner(tables: SimTables, wl: Workload, ep_of_rank: np.ndarray,
                  cfg: WorkloadSimConfig):
    key = (id(tables), id(wl), ep_of_rank.tobytes(), cfg.static_key())
    hit = _RUNNER_CACHE.get(key)
    if hit is not None and hit[0] is tables and hit[1] is wl:
        return hit[2]

    core = SwitchCore(tables, cfg.to_sim_config())
    n_ep, Qs, eids = core.n_ep, core.Qs, core.eids
    M = wl.n_messages
    assert M < MAX_MSGS, f"msg ids overflow packed records: {M}"

    src_ep = ep_of_rank[wl.src]
    dst_ep = ep_of_rank[wl.dst]
    size = jnp.asarray(wl.size.astype(np.int32))
    dep = jnp.asarray(wl.dep_matrix())                      # [M, Dmax]
    dst_r_of_msg = jnp.asarray(
        tables.ep_router[dst_ep].astype(np.int32))          # [M]

    # per-endpoint message lists (ascending id = topological order)
    per_ep = [np.nonzero(src_ep == e)[0] for e in range(n_ep)]
    kmax = max(1, max((len(v) for v in per_ep), default=1))
    mbe = np.full((n_ep, kmax), -1, dtype=np.int32)
    for e, v in enumerate(per_ep):
        mbe[e, :len(v)] = v
    msgs_by_ep = jnp.asarray(mbe)

    def fold(acc, g_net, g_src, pkt_net, pkt_src, cycle):
        # per-message flit accounting; message latency comes from the
        # carried start/done cycles, not a per-flit sum
        flits_del, delivered = acc
        mn = jnp.where(g_net, pk_msg(pkt_net), M)           # M = OOB drop
        ms = jnp.where(g_src, pk_msg(pkt_src), M)
        flits_del = flits_del.at[mn.reshape(-1)].add(1, mode="drop")
        flits_del = flits_del.at[ms].add(1, mode="drop")
        delivered = (delivered + g_net.sum().astype(jnp.int32)
                     + g_src.sum().astype(jnp.int32))
        return flits_del, delivered

    def make_step(c):
        """Step closure over a table-bound core (rank-polymorphic: the
        sweep engine vmaps it over a lane axis, DESIGN.md §10)."""
        return lambda carry, cycle: step(c, carry, cycle)

    def step(c, carry, cycle):
        (nq_pkt, nq_count, sq_pkt, sq_count,
         sent, flits_del, start_c, done_c, key) = carry
        key, k_rt = jax.random.split(key)

        occ = c.occupancy(nq_count)

        # ---- ready set over the DAG (dense mask, carried counters)
        done = flits_del >= size                            # [M]
        dep_ok = jnp.where(dep >= 0, done[jnp.maximum(dep, 0)],
                           True).all(axis=1)
        sendable = dep_ok & (sent < size)                   # [M]

        # ---- per-endpoint pick: lowest-id sendable message
        cand = (msgs_by_ep >= 0) & sendable[jnp.maximum(msgs_by_ep, 0)]
        has = cand.any(axis=1)                              # [n_ep]
        slot = jnp.argmax(cand, axis=1)
        mpick = jnp.where(has, msgs_by_ep[eids, slot], 0)

        # ---- inject one flit (same source-queue mechanics as open loop)
        want = has & (sq_count < Qs)
        dst_r = dst_r_of_msg[mpick]
        inter, phase = c.route_decision(dst_r, occ, k_rt)
        new_pkt = pack_record(dst_r, inter, cycle,
                              jnp.zeros((n_ep,), jnp.int32), phase,
                              msg=mpick)
        sq_pkt, sq_count = c.inject(sq_pkt, sq_count, want, new_pkt)
        msel = jnp.where(want, mpick, M)                    # M = OOB drop
        sent = sent.at[msel].add(1, mode="drop")
        start_c = start_c.at[msel].min(cycle, mode="drop")

        # ---- shared switch pipeline with the per-message fold
        (nq_pkt, nq_count, sq_pkt, sq_count,
         (flits_del, delivered)) = c.alloc(
             nq_pkt, nq_count, sq_pkt, sq_count,
             occ, cycle, fold, (flits_del, jnp.int32(0)))

        now_done = flits_del >= size
        done_c = jnp.where(now_done & (done_c == BIG), cycle + 1, done_c)
        stats = (want.sum().astype(jnp.int32), delivered,
                 now_done.sum().astype(jnp.int32))
        return (nq_pkt, nq_count, sq_pkt, sq_count,
                sent, flits_del, start_c, done_c, key), stats

    def run_chunk_const(carry, offset):
        cycles = offset + jnp.arange(cfg.chunk, dtype=jnp.int32)
        return jax.lax.scan(make_step(core), carry, cycles)

    def run_chunk_ops(table_ops, carry, offset):
        c = core.bind_tables(table_ops)
        cycles = offset + jnp.arange(cfg.chunk, dtype=jnp.int32)
        return jax.lax.scan(make_step(c), carry, cycles)

    def init_carry(key0):
        return core.init_queues() + (
            jnp.zeros((M,), jnp.int32),                     # sent
            jnp.zeros((M,), jnp.int32),                     # flits_delivered
            jnp.full((M,), BIG, jnp.int32),                 # start cycle
            jnp.full((M,), BIG, jnp.int32),                 # done cycle
            key0)

    # the carry is donated: it is threaded through every chunk call and
    # aliases the returned carry, so queue state is updated in place
    # across the whole chunked run (DESIGN.md §10).  run_chunk_ops is
    # the operand-tables variant the mask-varying lane sweep vmaps.
    fn = (jax.jit(run_chunk_const, donate_argnums=(0,)), init_carry,
          (run_chunk_const, run_chunk_ops))
    _cache_put(_RUNNER_CACHE, key, (tables, wl, fn))
    return fn


def _workload_result(wl: Workload, cfg: WorkloadSimConfig,
                     ep_of_rank: np.ndarray, msg_state: tuple,
                     per_cycle_dlv: np.ndarray, completed: bool,
                     cycles_run: int) -> WorkloadResult:
    """Host-side reduction of final message counters into a
    WorkloadResult (shared by `run_workload` and the lane sweep)."""
    sent, flits_del, start_c, done_c = (
        np.asarray(a, dtype=np.int64) for a in msg_state)
    big = int(BIG)
    msg_start = np.where(start_c < big, start_c, -1)
    msg_done = np.where(done_c < big, done_c, -1)
    makespan = float(done_c.max()) if completed else float("inf")

    return WorkloadResult(
        name=wl.name, mode=cfg.mode, placement=cfg.placement,
        n_ranks=wl.n_ranks, n_messages=wl.n_messages, completed=completed,
        makespan=makespan, cycles_run=cycles_run,
        flits_injected=int(sent.sum()),
        flits_delivered=int(flits_del.sum()),
        msg_size=wl.size.copy(), msg_phase=wl.phase.copy(),
        msg_sent=sent, msg_delivered=flits_del,
        msg_start=msg_start, msg_done=msg_done,
        per_cycle_delivered=per_cycle_dlv,
        ep_of_rank=ep_of_rank,
    )


def run_workload(tables: SimTables, wl: Workload,
                 cfg: WorkloadSimConfig = WorkloadSimConfig(),
                 ep_of_rank: Optional[np.ndarray] = None) -> WorkloadResult:
    """Simulate `wl` to completion (or cfg.max_cycles) and report JCT."""
    if ep_of_rank is None:
        ep_of_rank = place_ranks(tables, wl.n_ranks, cfg.placement,
                                 seed=cfg.seed)
    ep_of_rank = np.asarray(ep_of_rank, dtype=np.int32)
    run_chunk, init_carry, _ = _chunk_runner(tables, wl, ep_of_rank, cfg)

    carry = init_carry(jax.random.PRNGKey(cfg.seed))
    M = wl.n_messages
    per_cycle_dlv = []
    completed = False
    t = 0
    while t < cfg.max_cycles:
        carry, (inj, dlv, n_done) = run_chunk(carry, jnp.int32(t))
        per_cycle_dlv.append(np.asarray(dlv, dtype=np.int64))
        t += cfg.chunk
        if int(n_done[-1]) == M:
            completed = True
            break

    (_, _, _, _, sent, flits_del, start_c, done_c, _) = carry
    return _workload_result(wl, cfg, ep_of_rank,
                            (sent, flits_del, start_c, done_c),
                            np.concatenate(per_cycle_dlv), completed, t)


def _sweep_run_workload(tables: SimTables, wl: Workload,
                        cfg: Optional[WorkloadSimConfig] = None,
                        seeds=None,
                        ep_of_rank: Optional[np.ndarray] = None) -> list:
    """Lane-batched closed-loop runs over (tables, seed) lanes — the
    implementation behind `repro.sim.sweep.sweep_run_workload`.

    One vmap-ed chunk runner is compiled for all L lanes; the host
    loop keeps stepping until every lane reports all messages done (a
    finished lane idles inertly: nothing sendable, queues drained,
    done/start counters guarded against rewrite).  Per-lane results
    are bit-identical to sequential `run_workload` calls.
    """
    from ..sweep import _lane_count

    cfg = cfg or WorkloadSimConfig()
    seeds_l = ([cfg.seed] if seeds is None
               else [int(s) for s in np.atleast_1d(seeds)])
    L = _lane_count([("tables", tables.lanes), ("seeds", len(seeds_l))])
    seeds_l = seeds_l * (L if len(seeds_l) == 1 else 1)
    cfgs = [dataclasses.replace(cfg, seed=s) for s in seeds_l]

    if L == 1:
        return [run_workload(tables.lane(0), wl, cfgs[0],
                             ep_of_rank=ep_of_rank)]

    tab0 = tables.lane(0)
    if ep_of_rank is None:
        # placement must be lane-invariant (it shapes msgs_by_ep and is
        # baked into the compiled step); a seed-sensitive placement
        # with per-lane seeds would silently break the bit-exactness
        # contract, so refuse it instead of placing all lanes with one
        # seed
        placements = [place_ranks(tab0, wl.n_ranks, cfg.placement,
                                  seed=s) for s in seeds_l]
        if any(not np.array_equal(p, placements[0])
               for p in placements[1:]):
            raise ValueError(
                f"placement {cfg.placement!r} depends on the seed, so "
                f"per-lane seeds would place ranks differently per "
                f"lane; pass ep_of_rank= explicitly to pin one "
                f"placement for every lane")
        ep_of_rank = placements[0]
    ep_of_rank = np.asarray(ep_of_rank, dtype=np.int32)
    tables_vary = tables.lanes > 1
    _, init_carry, (chunk_const, chunk_ops) = _chunk_runner(
        tab0, wl, ep_of_rank, cfg)

    # mask-varying sweeps key structurally (one executable for any set
    # of failure samples of this topology); shared-table sweeps keep
    # the constants and key by table identity, like the single-lane path
    tab_key = tables_signature(tab0) if tables_vary else id(tab0)
    key = ("sweep", tab_key, id(wl), ep_of_rank.tobytes(),
           cfg.static_key(), L, tables_vary)
    hit = _RUNNER_CACHE.get(key)
    if hit is not None and hit[0] is wl and \
            (tables_vary or hit[1] is tab0):
        fn = hit[2]
    else:
        if tables_vary:
            table_axes = jax.tree_util.tree_map(
                lambda _: 0, SwitchCore.device_tables(tab0))
            fn = jax.jit(jax.vmap(chunk_ops,
                                  in_axes=(table_axes, 0, None)),
                         donate_argnums=(1,))
        else:
            fn = jax.jit(jax.vmap(chunk_const, in_axes=(0, None)),
                         donate_argnums=(0,))
        _cache_put(_RUNNER_CACHE, key, (wl, tab0, fn))

    lanes0 = [init_carry(jax.random.PRNGKey(s)) for s in seeds_l]
    carry = tuple(jnp.stack([l[i] for l in lanes0])
                  for i in range(len(lanes0[0])))
    table_ops = SwitchCore.device_tables(tables) if tables_vary else None

    M = wl.n_messages
    per_cycle_dlv = []
    done_lane = np.zeros(L, dtype=bool)
    t = 0
    while t < cfg.max_cycles:
        if tables_vary:
            carry, (inj, dlv, n_done) = fn(table_ops, carry, jnp.int32(t))
        else:
            carry, (inj, dlv, n_done) = fn(carry, jnp.int32(t))
        per_cycle_dlv.append(np.asarray(dlv, dtype=np.int64))   # [L, chunk]
        t += cfg.chunk
        done_lane = np.asarray(n_done)[:, -1] == M
        if done_lane.all():
            break

    (_, _, _, _, sent, flits_del, start_c, done_c, _) = carry
    dlv_all = np.concatenate(per_cycle_dlv, axis=1)             # [L, t]
    out = []
    for i in range(L):
        out.append(_workload_result(
            wl, cfgs[i], ep_of_rank,
            (sent[i], flits_del[i], start_c[i], done_c[i]),
            dlv_all[i], bool(done_lane[i]), t))
    return out
