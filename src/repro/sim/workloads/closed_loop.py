"""Closed-loop dependency-triggered workload engine (DESIGN.md §7, §11).

Runs one or more :class:`~repro.sim.workloads.ir.Workload` message-DAGs
to completion on the cycle-level flit simulator and measures job
completion time — the quantity the open-loop Bernoulli engine
(`repro.sim.engine.simulate`) structurally cannot produce.

The engine shares :class:`repro.sim.engine.SwitchCore` (credit view,
route choice, W-round allocation, compaction) with the open-loop
simulator; only injection and the ejection fold differ:

  - packet records carry an extra MSG field (bit-packed, see
    repro.sim.packed) naming the message a flit belongs to, so the
    ejection fold can scatter-add per-message delivered-flit counts;
  - each cycle the ready set is re-derived as a dense mask over DAG
    messages from the carried delivered-flit counters (`done[dep]`
    gather over the padded dep matrix), every endpoint injects one flit
    of its lowest-id ready unfinished message, and a message completes
    when its delivered count reaches its size;
  - the scan runs in fixed-size compiled chunks with a host-side
    all-done check between chunks: one trace/compile per (tables,
    workload, placement, config) signature regardless of makespan, and
    early exit at chunk granularity.

Multi-job generalisation (DESIGN.md §11): the compiled step works on a
CONCATENATED message space over J jobs (`_MsgSpace`).  Message ids are
global; the packed MSG field carries ``job << MSG_JOB_SHIFT | local``
so the ejection fold can recover the global id with one [J+1]-offset
gather.  Sendability is additionally gated on a per-job admit-cycle
vector carried in the scan state (set host-side by the admission
scheduler in `repro.sim.workloads.jobs`), and per-cycle stats report
per-job done-message counts.  A single job admitted at cycle 0 makes
every added term the identity, so `run_workload` results are
bit-identical to the pre-job-layer engine (golden-pinned in
tests/test_jobs.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import telemetry as tel
from ..engine import (BIG, SimConfig, SwitchCore, _cache_put,
                      tables_signature)
from ..packed import (MAX_JOB_MSGS, MAX_JOBS, MSG_JOB_SHIFT, pack_record,
                      pk_msg)
from ..tables import SimTables
from ..telemetry import TelemetryConfig, TelemetrySnapshot
from .ir import Workload
from .mapping import place_ranks

__all__ = ["WorkloadSimConfig", "WorkloadResult", "run_workload"]


@dataclasses.dataclass(frozen=True)
class WorkloadSimConfig:
    vcs: int = 4
    q_net: int = 16
    q_src: int = 64
    mode: str = "min"                 # min | val | ugal_l | ugal_g | ecmp
    # "table": route choice from the routing tables (the modes above);
    # "source": per-message explicit paths from a PolicyWorkload's
    # route_port/vc_base arrays (DESIGN.md §13) — requires mode="min"
    # (source routing bypasses adaptive choice; injection stays on the
    # MIN record layout so table-MIN runs stay bit-comparable)
    routing: str = "table"
    n_val_candidates: int = 4
    lookahead: int = 4
    seed: int = 0
    placement: str = "linear"         # see workloads.mapping.PLACEMENTS
    chunk: int = 256                  # cycles per compiled scan chunk
    max_cycles: int = 200_000         # give up (makespan = inf) past this
    kernel_path: str = "auto"         # auto | ref | pallas (DESIGN.md §9)
    # opt-in counters/tracing (repro.sim.telemetry); default off adds
    # zero carry leaves and is bit-exact vs a build without the layer
    telemetry: TelemetryConfig = TelemetryConfig()

    def to_sim_config(self) -> SimConfig:
        return SimConfig(vcs=self.vcs, q_net=self.q_net, q_src=self.q_src,
                         mode=self.mode,
                         n_val_candidates=self.n_val_candidates,
                         lookahead=self.lookahead, seed=self.seed,
                         kernel_path=self.kernel_path,
                         telemetry=self.telemetry)

    def static_key(self) -> tuple:
        # `routing` MUST be part of the key: a source-routed and a
        # table-routed runner for the same (tables, workload) trace
        # different steps, and sharing a cache slot would silently run
        # the wrong one (regression test in tests/test_policy.py)
        return (self.vcs, self.q_net, self.q_src, self.mode, self.routing,
                self.n_val_candidates, self.lookahead, self.placement,
                self.chunk, self.kernel_path,
                self.telemetry.static_key())


@dataclasses.dataclass
class WorkloadResult:
    name: str
    mode: str
    placement: str
    n_ranks: int
    n_messages: int
    completed: bool
    makespan: float                   # cycles; inf if hit max_cycles
    cycles_run: int
    flits_injected: int
    flits_delivered: int
    msg_size: np.ndarray              # [M]
    msg_phase: np.ndarray             # [M]
    msg_sent: np.ndarray              # [M] flits injected per message
    msg_delivered: np.ndarray         # [M] flits ejected per message
    msg_start: np.ndarray             # [M] first-injection cycle (-1 never)
    msg_done: np.ndarray              # [M] completion cycle (-1 never)
    per_cycle_delivered: np.ndarray   # [cycles_run]
    ep_of_rank: np.ndarray            # [n_ranks] the placement used
    telemetry: Optional[TelemetrySnapshot] = None

    @property
    def achieved_bw(self) -> float:
        """Delivered flits per cycle, fabric-level.

        Completed runs average over the makespan; incomplete (timed
        out) runs average over the cycles actually run — a degraded
        fabric that still moves flits must not plot as zero bandwidth
        just because the DAG missed the max_cycles deadline
        (`benchmarks/faults_sweep.py` relies on this).
        """
        span = (self.makespan if np.isfinite(self.makespan)
                else float(self.cycles_run))
        if span <= 0:
            return 0.0
        return float(self.flits_delivered / span)

    @property
    def avg_msg_latency(self) -> float:
        """Mean message start->completion time, completed messages."""
        ok = self.msg_done >= 0
        if not ok.any():
            return float("nan")
        return float((self.msg_done[ok] - self.msg_start[ok]).mean())


# ---------------------------------------------------------------------------
# concatenated multi-job message space
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _MsgSpace:
    """Host-side concatenation of J workload DAGs into one message
    space (global message ids; per-job offsets recover job-local ids).

    ``fid`` is the value injected into the packed MSG field:
    ``job << MSG_JOB_SHIFT | local_id``.  For J=1 it equals the global
    id, so single-job packet records are unchanged bit-for-bit.
    """
    n_jobs: int
    n_messages: int                   # Mtot over all jobs
    job_off: np.ndarray               # [J+1] cumulative message offsets
    src_ep: np.ndarray                # [Mtot]
    dst_ep: np.ndarray                # [Mtot]
    size: np.ndarray                  # [Mtot]
    dep: np.ndarray                   # [Mtot, Dmax] global ids, -1 pad
    fid: np.ndarray                   # [Mtot] packed MSG-field values


def _build_space(wls: Sequence[Workload],
                 eps: Sequence[np.ndarray]) -> _MsgSpace:
    assert len(wls) == len(eps) and len(wls) >= 1
    assert len(wls) <= MAX_JOBS, \
        f"{len(wls)} jobs overflow the {MAX_JOBS}-job MSG field budget"
    off = np.zeros(len(wls) + 1, dtype=np.int64)
    src_l, dst_l, size_l, dep_l, fid_l = [], [], [], [], []
    dmax = max(max(1, w.dep_matrix().shape[1]) for w in wls)
    for j, (wl, ep) in enumerate(zip(wls, eps)):
        m = wl.n_messages
        assert m < MAX_JOB_MSGS, \
            f"job {j}: {m} messages overflow the per-job id budget"
        off[j + 1] = off[j] + m
        src_l.append(ep[wl.src])
        dst_l.append(ep[wl.dst])
        size_l.append(wl.size.astype(np.int32))
        dm = np.full((m, dmax), -1, dtype=np.int32)
        d = wl.dep_matrix()
        dm[:, :d.shape[1]] = np.where(d >= 0, d + off[j], -1)
        dep_l.append(dm)
        fid_l.append((j << MSG_JOB_SHIFT) + np.arange(m, dtype=np.int32))
    return _MsgSpace(
        n_jobs=len(wls), n_messages=int(off[-1]), job_off=off,
        src_ep=np.concatenate(src_l).astype(np.int32),
        dst_ep=np.concatenate(dst_l).astype(np.int32),
        size=np.concatenate(size_l),
        dep=np.concatenate(dep_l, axis=0),
        fid=np.concatenate(fid_l))


# (tables, workloads, placement-bytes, static-config) -> compiled chunk
# runner.  The single-lane runner keeps the tables as closure constants
# (gather specialisation, see repro.sim.engine) and so recompiles per
# failure mask; the lane-batched sweep below lifts them into operands
# so all masks of one topology share one executable (DESIGN.md §10).
# Values pin the keyed objects against id() reuse, and the shared FIFO
# bound caps compiled-executable retention.
_RUNNER_CACHE: dict = {}


def _source_operands(wls: Sequence[Workload]) -> tuple:
    """Concatenated source-routing arrays over a job mix: route_port
    [Mtot, Hmax] (short paths right-padded with the eject sentinel) and
    vc_base [Mtot].  Every workload must be a lowered PolicyWorkload."""
    for j, w in enumerate(wls):
        if getattr(w, "route_port", None) is None:
            raise ValueError(
                f"job {j} ({w.name!r}): routing='source' needs "
                f"PolicyWorkloads (Policy.lower / emit_policy), got a "
                f"plain Workload with no route_port")
    H = max(w.route_port.shape[1] for w in wls)
    rps = [np.pad(w.route_port,
                  ((0, 0), (0, H - w.route_port.shape[1])),
                  constant_values=-1) for w in wls]
    return (np.concatenate(rps, axis=0).astype(np.int32),
            np.concatenate([w.vc_base for w in wls]).astype(np.int32))


def _space_runner(tables: SimTables, wls: Tuple[Workload, ...],
                  eps: Tuple[np.ndarray, ...], cfg: WorkloadSimConfig):
    """Compiled chunk runner over the concatenated message space of
    `wls` placed at `eps`.  Returns (jitted_runner, init_carry,
    (run_chunk_const, run_chunk_ops), space)."""
    key = (id(tables), tuple(id(w) for w in wls),
           tuple(e.tobytes() for e in eps), cfg.static_key())
    hit = _RUNNER_CACHE.get(key)
    if hit is not None and hit[0] is tables and hit[1] == tuple(wls):
        return hit[2]

    space = _build_space(wls, eps)
    core = SwitchCore(tables, cfg.to_sim_config())
    n_ep, Qs, eids = core.n_ep, core.Qs, core.eids
    M, J = space.n_messages, space.n_jobs

    size = jnp.asarray(space.size)
    dep = jnp.asarray(space.dep)                            # [M, Dmax]
    fid = jnp.asarray(space.fid)                            # [M]
    job_off = jnp.asarray(space.job_off.astype(np.int32))   # [J+1]
    dst_r_of_msg = jnp.asarray(
        tables.ep_router[space.dst_ep].astype(np.int32))    # [M]
    job_of_msg = jnp.asarray(np.repeat(
        np.arange(J, dtype=np.int32), np.diff(space.job_off)))  # [M]
    mid_mask = jnp.int32(MAX_JOB_MSGS - 1)

    # per-endpoint message lists (ascending GLOBAL id: topological
    # within each job, earlier-arriving job first across jobs)
    per_ep = [np.nonzero(space.src_ep == e)[0] for e in range(n_ep)]
    kmax = max(1, max((len(v) for v in per_ep), default=1))
    mbe = np.full((n_ep, kmax), -1, dtype=np.int32)
    for e, v in enumerate(per_ep):
        mbe[e, :len(v)] = v
    msgs_by_ep = jnp.asarray(mbe)

    def to_gid(field):
        # MSG field -> global message id; job ids of live packets are
        # always < J, min() only guards garbage in zero-initialised
        # queue slots (those are g=False and dropped anyway)
        j = jnp.minimum(field >> MSG_JOB_SHIFT, J - 1)
        return job_off[j] + (field & mid_mask)

    assert cfg.routing in ("table", "source"), cfg.routing
    if cfg.routing == "source":
        # explicit paths replace table route choice in the core; the
        # arrays ride as closure constants here (single schedule), the
        # schedule-search lane sweep below lifts them into operands
        assert cfg.mode == "min", \
            "routing='source' bypasses adaptive route choice; use " \
            "mode='min' (the paths themselves encode any detour)"
        rp, vb = _source_operands(wls)
        core = core.bind_source_routes(jnp.asarray(rp), jnp.asarray(vb),
                                       to_gid)

    def fold(acc, g_net, g_src, pkt_net, pkt_src, cycle):
        # per-message flit accounting; message latency comes from the
        # carried start/done cycles, not a per-flit sum
        flits_del, delivered = acc
        mn = jnp.where(g_net, to_gid(pk_msg(pkt_net)), M)    # M = OOB drop
        ms = jnp.where(g_src, to_gid(pk_msg(pkt_src)), M)
        flits_del = flits_del.at[mn.reshape(-1)].add(1, mode="drop")
        flits_del = flits_del.at[ms].add(1, mode="drop")
        delivered = (delivered + g_net.sum().astype(jnp.int32)
                     + g_src.sum().astype(jnp.int32))
        return flits_del, delivered

    def make_step(c):
        """Step closure over a table-bound core (rank-polymorphic: the
        sweep engine vmaps it over a lane axis, DESIGN.md §10)."""
        return lambda carry, cycle: step(c, carry, cycle)

    tcfg = core.tel
    # closed-loop tracing samples whole MESSAGES: every flit and hop of
    # a sampled message hashes the same packed MSG field
    sampler = (tel.trace.msg_sampler(tcfg.trace_sample_shift)
               if tcfg.trace else None)

    def step(c, carry, cycle):
        (nq_pkt, nq_count, sq_pkt, sq_count, admit,
         sent, flits_del, start_c, done_c, key, ts) = carry
        key, k_rt = jax.random.split(key)

        occ = c.occupancy(nq_count)

        # ---- ready set over the DAGs (dense mask, carried counters);
        # a message is sendable only once its job has been admitted
        done = flits_del >= size                            # [M]
        dep_ok = jnp.where(dep >= 0, done[jnp.maximum(dep, 0)],
                           True).all(axis=1)
        admitted = (cycle >= admit)[job_of_msg]             # [M]
        sendable = dep_ok & (sent < size) & admitted        # [M]

        # ---- per-endpoint pick: lowest-id sendable message
        cand = (msgs_by_ep >= 0) & sendable[jnp.maximum(msgs_by_ep, 0)]
        has = cand.any(axis=1)                              # [n_ep]
        slot = jnp.argmax(cand, axis=1)
        mpick = jnp.where(has, msgs_by_ep[eids, slot], 0)

        # ---- inject one flit (same source-queue mechanics as open loop)
        want = has & (sq_count < Qs)
        dst_r = dst_r_of_msg[mpick]
        inter, phase = c.route_decision(dst_r, occ, k_rt)
        new_pkt = pack_record(dst_r, inter, cycle,
                              jnp.zeros((n_ep,), jnp.int32), phase,
                              msg=fid[mpick])
        sq_pkt, sq_count = c.inject(sq_pkt, sq_count, want, new_pkt)
        msel = jnp.where(want, mpick, M)                    # M = OOB drop
        sent = sent.at[msel].add(1, mode="drop")
        start_c = start_c.at[msel].min(cycle, mode="drop")

        # ---- telemetry at the injection point (data-only)
        extra = None
        if tcfg.counters:
            ts = tel.TelemetryState(
                tel.counters.count_routes(ts.counters, want, phase),
                ts.trace)
        if tcfg.trace:
            extra = (want & sampler(new_pkt),
                     tel.trace.pack_events(cycle, tel.trace.KIND_INJECT,
                                           c.ep_router,
                                           tel.trace.PORT_EP, new_pkt))

        # ---- shared switch pipeline with the per-message fold
        (nq_pkt, nq_count, sq_pkt, sq_count,
         (flits_del, delivered), ts) = c.alloc(
             nq_pkt, nq_count, sq_pkt, sq_count,
             occ, cycle, fold, (flits_del, jnp.int32(0)),
             tel_state=ts, trace_sample=sampler, trace_extra=extra)

        now_done = flits_del >= size
        done_c = jnp.where(now_done & (done_c == BIG), cycle + 1, done_c)
        # per-job done-message counts without a scatter: job segments
        # are contiguous, so a cumsum difference at the offsets does it
        ncs = jnp.concatenate([
            jnp.zeros((1,), jnp.int32),
            jnp.cumsum(now_done.astype(jnp.int32))])
        n_done_job = ncs[job_off[1:]] - ncs[job_off[:-1]]   # [J]
        stats = (want.sum().astype(jnp.int32), delivered, n_done_job)
        return (nq_pkt, nq_count, sq_pkt, sq_count, admit,
                sent, flits_del, start_c, done_c, key, ts), stats

    def run_chunk_const(carry, offset):
        cycles = offset + jnp.arange(cfg.chunk, dtype=jnp.int32)
        return jax.lax.scan(make_step(core), carry, cycles)

    def run_chunk_ops(table_ops, carry, offset):
        c = core.bind_tables(table_ops)
        cycles = offset + jnp.arange(cfg.chunk, dtype=jnp.int32)
        return jax.lax.scan(make_step(c), carry, cycles)

    def init_carry(key0, admit0=None):
        if admit0 is None:
            admit0 = jnp.zeros((J,), jnp.int32)             # all at cycle 0
        return core.init_queues() + (
            jnp.asarray(admit0, jnp.int32),                 # admit cycles
            jnp.zeros((M,), jnp.int32),                     # sent
            jnp.zeros((M,), jnp.int32),                     # flits_delivered
            jnp.full((M,), BIG, jnp.int32),                 # start cycle
            jnp.full((M,), BIG, jnp.int32),                 # done cycle
            key0,
            tel.init_state(tcfg, core))                     # telemetry

    # the carry is donated: it is threaded through every chunk call and
    # aliases the returned carry, so queue state is updated in place
    # across the whole chunked run (DESIGN.md §10).  run_chunk_ops is
    # the operand-tables variant the mask-varying lane sweep vmaps.
    fn = (jax.jit(run_chunk_const, donate_argnums=(0,)), init_carry,
          (run_chunk_const, run_chunk_ops), space)
    _cache_put(_RUNNER_CACHE, key, (tables, tuple(wls), fn))
    return fn


def _chunk_runner(tables: SimTables, wl: Workload, ep_of_rank: np.ndarray,
                  cfg: WorkloadSimConfig):
    """Single-workload runner: the J=1 degenerate of `_space_runner`."""
    run, init_carry, variants, _ = _space_runner(
        tables, (wl,), (np.asarray(ep_of_rank, np.int32),), cfg)
    return run, init_carry, variants


def _workload_result(wl: Workload, cfg: WorkloadSimConfig,
                     ep_of_rank: np.ndarray, msg_state: tuple,
                     per_cycle_dlv: np.ndarray, completed: bool,
                     cycles_run: int, tel_state=None) -> WorkloadResult:
    """Host-side reduction of final message counters into a
    WorkloadResult (shared by `run_workload` and the lane sweep)."""
    sent, flits_del, start_c, done_c = (
        np.asarray(a, dtype=np.int64) for a in msg_state)
    big = int(BIG)
    msg_start = np.where(start_c < big, start_c, -1)
    msg_done = np.where(done_c < big, done_c, -1)
    makespan = float(done_c.max()) if completed else float("inf")
    if completed:
        # the chunked host loop runs past completion to the chunk
        # boundary; trim the accounting to the true makespan (the
        # trailing cycles are post-completion and deliver nothing)
        cycles_run = int(done_c.max())
        per_cycle_dlv = per_cycle_dlv[:cycles_run]
    # counters normalise over the trimmed span: the overrun cycles are
    # post-drain (queues empty, no grants) so only occ_sum would be
    # diluted by including them
    snap = tel.snapshot(cfg.telemetry, tel_state, cycles_run)

    return WorkloadResult(
        name=wl.name, mode=cfg.mode, placement=cfg.placement,
        n_ranks=wl.n_ranks, n_messages=wl.n_messages, completed=completed,
        makespan=makespan, cycles_run=cycles_run,
        flits_injected=int(sent.sum()),
        flits_delivered=int(flits_del.sum()),
        msg_size=wl.size.copy(), msg_phase=wl.phase.copy(),
        msg_sent=sent, msg_delivered=flits_del,
        msg_start=msg_start, msg_done=msg_done,
        per_cycle_delivered=per_cycle_dlv,
        ep_of_rank=ep_of_rank,
        telemetry=snap,
    )


def run_workload(tables: SimTables, wl: Workload,
                 cfg: WorkloadSimConfig = WorkloadSimConfig(),
                 ep_of_rank: Optional[np.ndarray] = None) -> WorkloadResult:
    """Simulate `wl` to completion (or cfg.max_cycles) and report JCT."""
    if ep_of_rank is None:
        # a lowered PolicyWorkload bakes the placement its explicit
        # paths assume; honour it in BOTH routing modes so source vs
        # table comparisons run the same ranks on the same endpoints
        ep_of_rank = getattr(wl, "ep_of_rank", None)
    if ep_of_rank is None:
        ep_of_rank = place_ranks(tables, wl.n_ranks, cfg.placement,
                                 seed=cfg.seed)
    ep_of_rank = np.asarray(ep_of_rank, dtype=np.int32)
    run_chunk, init_carry, _ = _chunk_runner(tables, wl, ep_of_rank, cfg)

    carry = init_carry(jax.random.PRNGKey(cfg.seed))
    M = wl.n_messages
    per_cycle_dlv = []
    completed = False
    t = 0
    while t < cfg.max_cycles:
        carry, (inj, dlv, n_done) = run_chunk(carry, jnp.int32(t))
        per_cycle_dlv.append(np.asarray(dlv, dtype=np.int64))
        t += cfg.chunk
        if int(n_done[-1, 0]) == M:
            completed = True
            break

    (_, _, _, _, _, sent, flits_del, start_c, done_c, _, ts) = carry
    return _workload_result(wl, cfg, ep_of_rank,
                            (sent, flits_del, start_c, done_c),
                            np.concatenate(per_cycle_dlv), completed, t,
                            tel_state=ts)


def _sweep_run_workload(tables: SimTables, wl: Workload,
                        cfg: Optional[WorkloadSimConfig] = None,
                        seeds=None,
                        ep_of_rank: Optional[np.ndarray] = None) -> list:
    """Lane-batched closed-loop runs over (tables, seed) lanes — the
    implementation behind `repro.sim.sweep.sweep_run_workload`.

    One vmap-ed chunk runner is compiled for all L lanes; the host
    loop keeps stepping until every lane reports all messages done (a
    finished lane idles inertly: nothing sendable, queues drained,
    done/start counters guarded against rewrite).  Per-lane results
    are bit-identical to sequential `run_workload` calls.

    Lanes vary DATA only (DESIGN.md §10): the job mix and placement
    are part of the traced step, so the sweep runs the single-job
    (J=1, admitted-at-0) degenerate of the multi-job engine.
    """
    from ..sweep import _lane_count

    cfg = cfg or WorkloadSimConfig()
    if ep_of_rank is None:
        ep_of_rank = getattr(wl, "ep_of_rank", None)
    seeds_l = ([cfg.seed] if seeds is None
               else [int(s) for s in np.atleast_1d(seeds)])
    L = _lane_count([("tables", tables.lanes), ("seeds", len(seeds_l))])
    seeds_l = seeds_l * (L if len(seeds_l) == 1 else 1)
    cfgs = [dataclasses.replace(cfg, seed=s) for s in seeds_l]

    if L == 1:
        return [run_workload(tables.lane(0), wl, cfgs[0],
                             ep_of_rank=ep_of_rank)]

    tab0 = tables.lane(0)
    if ep_of_rank is None:
        # placement must be lane-invariant (it shapes msgs_by_ep and is
        # baked into the compiled step); a seed-sensitive placement
        # with per-lane seeds would silently break the bit-exactness
        # contract, so refuse it instead of placing all lanes with one
        # seed
        placements = [place_ranks(tab0, wl.n_ranks, cfg.placement,
                                  seed=s) for s in seeds_l]
        if any(not np.array_equal(p, placements[0])
               for p in placements[1:]):
            raise ValueError(
                f"placement {cfg.placement!r} depends on the seed, so "
                f"per-lane seeds would place ranks differently per "
                f"lane; pass ep_of_rank= explicitly to pin one "
                f"placement for every lane")
        ep_of_rank = placements[0]
    ep_of_rank = np.asarray(ep_of_rank, dtype=np.int32)
    tables_vary = tables.lanes > 1
    _, init_carry, (chunk_const, chunk_ops) = _chunk_runner(
        tab0, wl, ep_of_rank, cfg)

    # mask-varying sweeps key structurally (one executable for any set
    # of failure samples of this topology); shared-table sweeps keep
    # the constants and key by table identity, like the single-lane path
    tab_key = tables_signature(tab0) if tables_vary else id(tab0)
    key = ("sweep", tab_key, id(wl), ep_of_rank.tobytes(),
           cfg.static_key(), L, tables_vary)
    hit = _RUNNER_CACHE.get(key)
    if hit is not None and hit[0] is wl and \
            (tables_vary or hit[1] is tab0):
        fn = hit[2]
    else:
        if tables_vary:
            table_axes = jax.tree_util.tree_map(
                lambda _: 0, SwitchCore.device_tables(tab0))
            fn = jax.jit(jax.vmap(chunk_ops,
                                  in_axes=(table_axes, 0, None)),
                         donate_argnums=(1,))
        else:
            fn = jax.jit(jax.vmap(chunk_const, in_axes=(0, None)),
                         donate_argnums=(0,))
        _cache_put(_RUNNER_CACHE, key, (wl, tab0, fn))

    lanes0 = [init_carry(jax.random.PRNGKey(s)) for s in seeds_l]
    # tree_map (not a per-element jnp.stack): the telemetry carry
    # element is a nested pytree — or () when telemetry is off
    carry = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *lanes0)
    table_ops = SwitchCore.device_tables(tables) if tables_vary else None

    M = wl.n_messages
    per_cycle_dlv = []
    done_lane = np.zeros(L, dtype=bool)
    t = 0
    while t < cfg.max_cycles:
        if tables_vary:
            carry, (inj, dlv, n_done) = fn(table_ops, carry, jnp.int32(t))
        else:
            carry, (inj, dlv, n_done) = fn(carry, jnp.int32(t))
        per_cycle_dlv.append(np.asarray(dlv, dtype=np.int64))   # [L, chunk]
        t += cfg.chunk
        done_lane = np.asarray(n_done)[:, -1, 0] == M
        if done_lane.all():
            break

    (_, _, _, _, _, sent, flits_del, start_c, done_c, _, ts) = carry
    dlv_all = np.concatenate(per_cycle_dlv, axis=1)             # [L, t]
    out = []
    for i in range(L):
        ts_i = jax.tree_util.tree_map(lambda a, i=i: a[i], ts)
        out.append(_workload_result(
            wl, cfgs[i], ep_of_rank,
            (sent[i], flits_del[i], start_c[i], done_c[i]),
            dlv_all[i], bool(done_lane[i]), t, tel_state=ts_i))
    return out


# ---------------------------------------------------------------------------
# lane-batched policy scoring (schedule search, DESIGN.md §13)
# ---------------------------------------------------------------------------

def _policy_sweep_runner(tables: SimTables, cfg: WorkloadSimConfig,
                         M: int, dmax: int, kmax: int, hmax: int,
                         n_ep: int):
    """Compiled lane-batched SOURCE-ROUTED runner whose WORKLOAD arrays
    are traced operands: one executable scores any generation of
    candidate schedules padded to the common shapes (M messages, dmax
    dep fan-in, kmax messages/endpoint, hmax path hops).

    This is the §10 lane contract pushed one level further: lanes here
    vary not just rate/seed/mask DATA but the schedule itself —
    size/dep/dst_r/msgs_by_ep/route_port/vc_base all become per-lane
    operands, while the routing tables stay closure constants (the
    search fixes one topology).  Per-lane results are bit-identical to
    single-lane `run_workload(routing='source')` calls on the same
    padded arrays (tests/test_policy.py).
    """
    key = ("policy-sweep", id(tables), cfg.static_key(),
           M, dmax, kmax, hmax)
    hit = _RUNNER_CACHE.get(key)
    if hit is not None and hit[0] is tables:
        return hit[2]

    assert cfg.routing == "source" and cfg.mode == "min"
    assert not cfg.telemetry.enabled, \
        "schedule search runs with telemetry off (per-lane traces of " \
        "operand-varying workloads are not supported)"
    core = SwitchCore(tables, cfg.to_sim_config())
    assert n_ep == core.n_ep
    Qs, eids = core.Qs, core.eids
    mid_mask = jnp.int32(MAX_JOB_MSGS - 1)

    def to_gid(field):
        # single-job id space: MSG field == global message id (the
        # mask only launders garbage in zero-initialised queue slots)
        return field & mid_mask

    def run_chunk(ops, carry, offset):
        c = core.bind_source_routes(ops["route_port"], ops["vc_base"],
                                    to_gid)
        size, dep = ops["size"], ops["dep"]
        dst_r_of_msg, msgs_by_ep = ops["dst_r"], ops["msgs_by_ep"]

        def fold(acc, g_net, g_src, pkt_net, pkt_src, cyc):
            flits_del, delivered = acc
            mn = jnp.where(g_net, to_gid(pk_msg(pkt_net)), M)
            ms = jnp.where(g_src, to_gid(pk_msg(pkt_src)), M)
            flits_del = flits_del.at[mn.reshape(-1)].add(1, mode="drop")
            flits_del = flits_del.at[ms].add(1, mode="drop")
            delivered = (delivered + g_net.sum().astype(jnp.int32)
                         + g_src.sum().astype(jnp.int32))
            return flits_del, delivered

        def step(carry, cycle):
            (nq_pkt, nq_count, sq_pkt, sq_count, admit,
             sent, flits_del, start_c, done_c, key, ts) = carry
            key, k_rt = jax.random.split(key)
            occ = c.occupancy(nq_count)

            done = flits_del >= size
            dep_ok = jnp.where(dep >= 0, done[jnp.maximum(dep, 0)],
                               True).all(axis=1)
            sendable = dep_ok & (sent < size) & (cycle >= admit[0])
            cand = (msgs_by_ep >= 0) & sendable[jnp.maximum(msgs_by_ep, 0)]
            has = cand.any(axis=1)
            # first sendable slot in the ROW ORDER of msgs_by_ep — the
            # entry-ordering knob the search permutes per lane
            slot = jnp.argmax(cand, axis=1)
            mpick = jnp.where(has, msgs_by_ep[eids, slot], 0)

            want = has & (sq_count < Qs)
            dst_r = dst_r_of_msg[mpick]
            inter, phase = c.route_decision(dst_r, occ, k_rt)
            new_pkt = pack_record(dst_r, inter, cycle,
                                  jnp.zeros((n_ep,), jnp.int32), phase,
                                  msg=mpick)
            sq_pkt, sq_count = c.inject(sq_pkt, sq_count, want, new_pkt)
            msel = jnp.where(want, mpick, M)
            sent = sent.at[msel].add(1, mode="drop")
            start_c = start_c.at[msel].min(cycle, mode="drop")

            (nq_pkt, nq_count, sq_pkt, sq_count,
             (flits_del, delivered), ts) = c.alloc(
                 nq_pkt, nq_count, sq_pkt, sq_count,
                 occ, cycle, fold, (flits_del, jnp.int32(0)),
                 tel_state=ts)

            now_done = flits_del >= size
            done_c = jnp.where(now_done & (done_c == BIG), cycle + 1,
                               done_c)
            n_done = now_done.astype(jnp.int32).sum()[None]     # [J=1]
            stats = (want.sum().astype(jnp.int32), delivered, n_done)
            return (nq_pkt, nq_count, sq_pkt, sq_count, admit,
                    sent, flits_del, start_c, done_c, key, ts), stats

        cycles = offset + jnp.arange(cfg.chunk, dtype=jnp.int32)
        return jax.lax.scan(step, carry, cycles)

    def init_carry(key0):
        return core.init_queues() + (
            jnp.zeros((1,), jnp.int32),                 # admit (cycle 0)
            jnp.zeros((M,), jnp.int32),                 # sent
            jnp.zeros((M,), jnp.int32),                 # flits_delivered
            jnp.full((M,), BIG, jnp.int32),             # start cycle
            jnp.full((M,), BIG, jnp.int32),             # done cycle
            key0,
            tel.init_state(cfg.telemetry, core))        # () — tel off

    ops_axes = {"size": 0, "dep": 0, "dst_r": 0, "msgs_by_ep": 0,
                "route_port": 0, "vc_base": 0}
    fn = (jax.jit(jax.vmap(run_chunk, in_axes=(ops_axes, 0, None)),
                  donate_argnums=(1,)), init_carry)
    _cache_put(_RUNNER_CACHE, key, (tables, None, fn))
    return fn


def _policy_operands(wl, M: int, dmax: int, kmax: int, hmax: int,
                     n_ep: int) -> dict:
    """One candidate's step operands, padded to the generation's common
    shapes.  Pad messages get size 0: 'done' from cycle one (0 >= 0)
    yet never sendable (sent < 0 is false), so they are inert and the
    all-done count M is lane-uniform."""
    m = wl.n_messages
    assert m <= M and wl.route_port.shape[1] <= hmax
    size = np.zeros(M, np.int32)
    size[:m] = wl.size
    dep = np.full((M, dmax), -1, np.int32)
    d = wl.dep_matrix()
    assert d.shape[1] <= dmax
    dep[:m, :d.shape[1]] = d
    dst_r = np.zeros(M, np.int32)
    dst_r[:m] = wl.dst_r_of_msg
    rp = np.full((M, hmax), -1, np.int32)
    rp[:m, :wl.route_port.shape[1]] = wl.route_port
    vb = np.zeros(M, np.int32)
    vb[:m] = wl.vc_base
    src_ep = wl.src_ep_of_msg
    mbe = np.full((n_ep, kmax), -1, np.int32)
    for e in range(n_ep):
        v = np.nonzero(src_ep == e)[0]
        assert len(v) <= kmax
        mbe[e, :len(v)] = v
    return {"size": size, "dep": dep, "dst_r": dst_r, "msgs_by_ep": mbe,
            "route_port": rp, "vc_base": vb}


def _sweep_run_policies(tables: SimTables, wls: Sequence[Workload],
                        cfg: Optional[WorkloadSimConfig] = None,
                        pad_to: Optional[tuple] = None) -> list:
    """Score L candidate schedules (lowered PolicyWorkloads) in ONE
    lane-batched source-routed run — the fitness evaluator behind
    `repro.sim.workloads.search` (exposed as
    `repro.sim.sweep.sweep_run_policies`).

    Candidates may differ in message count, chunking, dependency
    structure, paths, VC classes, per-endpoint ordering and placement:
    everything is padded to common shapes (`pad_to` = (M, dmax, kmax,
    hmax) pins them across generations so the whole search reuses one
    compiled executable) and varied per lane as traced operands.
    Returns one WorkloadResult per candidate, bit-identical to
    sequential `run_workload(routing='source')` calls.
    """
    cfg = cfg or WorkloadSimConfig(routing="source")
    assert tables.lanes == 1, \
        "policy sweeps vary the SCHEDULE per lane; topology is fixed"
    wls = list(wls)
    assert wls, "empty candidate list"
    n_ep = tables.n_endpoints
    for w in wls:
        if getattr(w, "route_port", None) is None:
            raise ValueError(f"{w.name!r}: candidates must be lowered "
                             f"PolicyWorkloads")
        w.dst_r_of_msg = tables.ep_router[
            w.ep_of_rank[w.dst]].astype(np.int32)
        w.src_ep_of_msg = w.ep_of_rank[w.src].astype(np.int32)

    need = (max(w.n_messages for w in wls),
            max(w.dep_matrix().shape[1] for w in wls),
            max(int(np.bincount(w.src_ep_of_msg,
                                minlength=n_ep).max()) for w in wls),
            max(w.route_port.shape[1] for w in wls))
    if pad_to is None:
        pad_to = need
    assert all(p >= n for p, n in zip(pad_to, need)), (pad_to, need)
    M, dmax, kmax, hmax = pad_to

    fn, init_carry = _policy_sweep_runner(tables, cfg, M, dmax, kmax,
                                          hmax, n_ep)
    ops_l = [_policy_operands(w, M, dmax, kmax, hmax, n_ep) for w in wls]
    ops = {k: jnp.asarray(np.stack([o[k] for o in ops_l]))
           for k in ops_l[0]}
    lanes0 = [init_carry(jax.random.PRNGKey(cfg.seed)) for _ in wls]
    carry = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *lanes0)

    L = len(wls)
    per_cycle_dlv = []
    done_lane = np.zeros(L, dtype=bool)
    t = 0
    while t < cfg.max_cycles:
        carry, (inj, dlv, n_done) = fn(ops, carry, jnp.int32(t))
        per_cycle_dlv.append(np.asarray(dlv, dtype=np.int64))
        t += cfg.chunk
        done_lane = np.asarray(n_done)[:, -1, 0] == M
        if done_lane.all():
            break

    (_, _, _, _, _, sent, flits_del, start_c, done_c, _, _) = carry
    dlv_all = np.concatenate(per_cycle_dlv, axis=1)
    out = []
    for i, w in enumerate(wls):
        m = w.n_messages
        out.append(_workload_result(
            w, cfg, w.ep_of_rank,
            (sent[i][:m], flits_del[i][:m], start_c[i][:m],
             done_c[i][:m]),
            dlv_all[i], bool(done_lane[i]), t))
    return out
