"""Workload IR: a message-DAG over logical ranks (DESIGN.md §7).

A :class:`Workload` is a flat list of M messages, each
``(src_rank, dst_rank, size_flits, deps, phase)``, where ``deps`` names
the messages that must be fully DELIVERED before this one may start
injecting.  This is the dependency-triggered semantics of CCL
simulators (cf. SNIPPETS.md: a policy entry fires only when its source
owns the chunk): the closed-loop engine carries the done-mask in its
scan state and re-derives the ready set every cycle.

Builders cover the paper's workload claims (§I/§V "stencil or graph
computations") plus the collective patterns measured on real Slim Fly
hardware by Blach et al. (arXiv:2310.03742):

  - ring_all_reduce:      2(k-1) serialized neighbour steps (NCCL ring)
  - recursive_doubling_all_reduce: log2(k) exchange rounds
  - all_to_all:           the MoE-shuffle personalized exchange
  - stencil:              2D/3D halo exchange over `iters` timesteps
  - graph_scatter:        degree-skewed vertex scatter supersteps

All builders emit messages in a topological order of the DAG (message
id increases along every dependency edge), which `validate` checks —
the engine's per-endpoint FIFO pick relies on it being *a* valid order,
and tests rely on Kahn's algorithm agreeing.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

__all__ = [
    "Workload",
    "ring_all_reduce",
    "ring_reduce_scatter",
    "ring_all_gather",
    "recursive_doubling_all_reduce",
    "all_to_all",
    "stencil",
    "graph_scatter",
    "make_workload",
]


@dataclasses.dataclass
class Workload:
    name: str
    n_ranks: int
    src: np.ndarray                  # [M] int32 source rank
    dst: np.ndarray                  # [M] int32 destination rank
    size: np.ndarray                 # [M] int32 flits per message
    deps: List[np.ndarray]           # per-message predecessor message ids
    phase: np.ndarray                # [M] int32 phase label per message
    phase_names: Tuple[str, ...] = ("phase0",)

    @property
    def n_messages(self) -> int:
        return int(self.src.shape[0])

    @property
    def total_flits(self) -> int:
        return int(self.size.sum())

    def dep_matrix(self) -> np.ndarray:
        """Dense [M, Dmax] predecessor ids, -1 padded (Dmax >= 1).

        The engine gathers `done[dep_matrix]` each cycle, so Dmax is the
        max in-DAG fan-in — small for collectives/stencil, up to the max
        vertex in-degree for graph scatter.
        """
        dmax = max(1, max((len(d) for d in self.deps), default=1))
        out = np.full((self.n_messages, dmax), -1, dtype=np.int32)
        for m, d in enumerate(self.deps):
            out[m, :len(d)] = d
        return out

    def validate(self) -> None:
        m = self.n_messages
        assert len(self.deps) == m and len(self.phase) == m
        assert (self.size > 0).all(), "zero-flit message"
        for arr in (self.src, self.dst):
            assert ((0 <= arr) & (arr < self.n_ranks)).all()
        assert (self.src != self.dst).all(), "self-send message"
        for i, d in enumerate(self.deps):
            for j in d:
                assert 0 <= j < m, (i, j)
                assert j < i, f"messages not topologically ordered: {j} -> {i}"
        assert int(self.phase.max(initial=0)) < len(self.phase_names)


def _finalize(name, n_ranks, rows, phase_names) -> Workload:
    """rows: list of (src, dst, size, deps, phase)."""
    src = np.array([r[0] for r in rows], dtype=np.int32)
    dst = np.array([r[1] for r in rows], dtype=np.int32)
    size = np.array([r[2] for r in rows], dtype=np.int32)
    deps = [np.asarray(r[3], dtype=np.int32) for r in rows]
    phase = np.array([r[4] for r in rows], dtype=np.int32)
    wl = Workload(name, n_ranks, src, dst, size, deps, phase,
                  tuple(phase_names))
    wl.validate()
    return wl


# ---------------------------------------------------------------------------
# collectives
# ---------------------------------------------------------------------------

def _ring_rows(k: int, chunk_flits: int, n_steps: int,
               phase_of_step) -> list:
    """`n_steps` serialized neighbour rounds of the NCCL ring: at step s
    rank r forwards one chunk to (r+1)%k, gated on the chunk it
    received at step s-1 from (r-1)%k."""
    rows = []
    for s in range(n_steps):
        for r in range(k):
            deps = [] if s == 0 else [(s - 1) * k + (r - 1) % k]
            rows.append((r, (r + 1) % k, chunk_flits, deps,
                         phase_of_step(s)))
    return rows


def ring_all_reduce(n_ranks: int, chunk_flits: int) -> Workload:
    """NCCL-style ring: 2(k-1) steps; at step s rank r forwards one
    payload/k chunk to (r+1)%k, gated on the chunk it received at step
    s-1 from (r-1)%k.  `chunk_flits` is the per-step message (payload/k);
    the modelled per-participant payload is k*chunk_flits."""
    k = n_ranks
    assert k >= 2
    rows = _ring_rows(k, chunk_flits, 2 * (k - 1),
                      lambda s: 0 if s < k - 1 else 1)
    return _finalize(f"ring_all_reduce(k={k},c={chunk_flits})", k, rows,
                     ("reduce_scatter", "all_gather"))


def ring_reduce_scatter(n_ranks: int, chunk_flits: int) -> Workload:
    """The first half of the ring all-reduce alone: k-1 neighbour steps
    after which rank r owns the reduced chunk (r+1)%k."""
    k = n_ranks
    assert k >= 2
    rows = _ring_rows(k, chunk_flits, k - 1, lambda s: 0)
    return _finalize(f"ring_reduce_scatter(k={k},c={chunk_flits})", k,
                     rows, ("reduce_scatter",))


def ring_all_gather(n_ranks: int, chunk_flits: int) -> Workload:
    """The second half alone: each rank starts owning one chunk and
    circulates it k-1 neighbour steps until everyone holds all k."""
    k = n_ranks
    assert k >= 2
    rows = _ring_rows(k, chunk_flits, k - 1, lambda s: 0)
    return _finalize(f"ring_all_gather(k={k},c={chunk_flits})", k,
                     rows, ("all_gather",))


def recursive_doubling_all_reduce(n_ranks: int, size_flits: int) -> Workload:
    """log2(k) rounds; at round s rank r exchanges the full vector with
    r XOR 2^s, gated on the round-(s-1) message it received."""
    k = n_ranks
    assert k >= 2 and (k & (k - 1)) == 0, "k must be a power of two"
    n_steps = k.bit_length() - 1
    rows = []
    for s in range(n_steps):
        for r in range(k):
            partner = r ^ (1 << s)
            # r's round-s send waits on the round-(s-1) message INTO r
            deps = [] if s == 0 else [(s - 1) * k + (r ^ (1 << (s - 1)))]
            rows.append((r, partner, size_flits, deps, s))
    return _finalize(f"recdbl_all_reduce(k={k},n={size_flits})", k, rows,
                     tuple(f"round{s}" for s in range(n_steps)))


def all_to_all(n_ranks: int, flits_per_pair: int) -> Workload:
    """Personalized all-to-all (the MoE expert shuffle): k(k-1)
    independent messages, rotated so rank r's j-th send targets
    (r+j)%k (no synchronized hotspot on rank 0)."""
    k = n_ranks
    assert k >= 2
    rows = []
    for r in range(k):
        for j in range(1, k):
            rows.append((r, (r + j) % k, flits_per_pair, [], 0))
    return _finalize(f"all_to_all(k={k},m={flits_per_pair})", k, rows,
                     ("shuffle",))


# ---------------------------------------------------------------------------
# HPC patterns
# ---------------------------------------------------------------------------

def _grid_neighbors(dims: Sequence[int]) -> List[np.ndarray]:
    """Periodic +/-1 neighbours per flattened grid rank (self excluded,
    deduped — a dim of size 2 has one neighbour on that axis)."""
    dims = tuple(int(d) for d in dims)
    n = int(np.prod(dims))
    coords = np.stack(np.unravel_index(np.arange(n), dims), axis=1)
    out = []
    for r in range(n):
        nbrs = set()
        for ax in range(len(dims)):
            for step in (-1, 1):
                c = coords[r].copy()
                c[ax] = (c[ax] + step) % dims[ax]
                v = int(np.ravel_multi_index(c, dims))
                if v != r:
                    nbrs.add(v)
        out.append(np.array(sorted(nbrs), dtype=np.int32))
    return out


def stencil(dims: Sequence[int], halo_flits: int, iters: int = 2) -> Workload:
    """2D/3D halo exchange: every iteration each rank sends its halo to
    all grid neighbours; iteration t sends are gated on ALL of the
    rank's iteration t-1 receives (the local compute barrier)."""
    dims = tuple(int(d) for d in dims)
    assert len(dims) in (2, 3) and min(dims) >= 2 and iters >= 1
    n = int(np.prod(dims))
    nbrs = _grid_neighbors(dims)
    rows = []
    # msg id lookup for deps: id_of[t][r] = ids of iteration-t sends of r
    prev_into: List[List[int]] = [[] for _ in range(n)]
    for t in range(iters):
        cur_into: List[List[int]] = [[] for _ in range(n)]
        for r in range(n):
            for v in nbrs[r]:
                mid = len(rows)
                rows.append((r, int(v), halo_flits, list(prev_into[r]), t))
                cur_into[v].append(mid)
        prev_into = cur_into
    return _finalize(
        f"stencil{len(dims)}d({'x'.join(map(str, dims))},h={halo_flits},"
        f"T={iters})", n, rows, tuple(f"iter{t}" for t in range(iters)))


def graph_scatter(n_ranks: int, flits: int, iters: int = 2,
                  skew: float = 1.4, max_degree: int = 0,
                  seed: int = 0) -> Workload:
    """Vertex-scatter supersteps on a fixed degree-skewed random graph
    (Zipf out-degrees — a few hub ranks fan out to many peers).  A
    superstep-t scatter from r is gated on all of r's superstep t-1
    receives; ranks with no inbound edges fire immediately (asynchronous
    frontier, not a global barrier)."""
    k = n_ranks
    assert k >= 2 and iters >= 1
    rng = np.random.default_rng(seed)
    cap = max_degree if max_degree > 0 else k - 1
    deg = np.minimum(rng.zipf(skew, size=k), min(cap, k - 1))
    targets = []
    for r in range(k):
        others = np.concatenate([np.arange(r), np.arange(r + 1, k)])
        targets.append(np.sort(rng.choice(others, size=int(deg[r]),
                                          replace=False)).astype(np.int32))
    rows = []
    prev_into: List[List[int]] = [[] for _ in range(k)]
    for t in range(iters):
        cur_into: List[List[int]] = [[] for _ in range(k)]
        for r in range(k):
            for v in targets[r]:
                mid = len(rows)
                rows.append((r, int(v), flits, list(prev_into[r]), t))
                cur_into[v].append(mid)
        prev_into = cur_into
    return _finalize(
        f"graph_scatter(k={k},m={flits},T={iters},s={skew})", k, rows,
        tuple(f"superstep{t}" for t in range(iters)))


_BUILDERS = {
    "ring_all_reduce": ring_all_reduce,
    "ring_reduce_scatter": ring_reduce_scatter,
    "ring_all_gather": ring_all_gather,
    "recdbl_all_reduce": recursive_doubling_all_reduce,
    "all_to_all": all_to_all,
    "stencil": stencil,
    "graph_scatter": graph_scatter,
}


def make_workload(kind: str, **kw) -> Workload:
    """Name-based builder dispatch (benchmarks / example CLI)."""
    if kind not in _BUILDERS:
        raise ValueError(f"unknown workload {kind!r}; "
                         f"have {sorted(_BUILDERS)}")
    return _BUILDERS[kind](**kw)
