"""Explicit-path collective policy IR (DESIGN.md §13).

A :class:`Policy` is the CCL-simulator-style schedule description
(SNIPPETS.md #1): a flat list of entries

    (chunk_id, src_rank, dst_rank, vc_class, size_flits, path)

where ``path`` is an EXPLICIT router sequence from the source rank's
router to the destination rank's router, and an entry fires only when
its source rank owns ``chunk_id`` (dependency-trigger semantics —
materialised here as an explicit ``deps`` tuple of entry ids, either
given directly or derived from chunk ownership by
:func:`from_transfers`).

Two lowerings connect the IR to the rest of the stack:

  - :meth:`Policy.lower` turns a policy into a
    :class:`PolicyWorkload` — a plain message-DAG
    (`repro.sim.workloads.ir.Workload`, so `run_workload` / `run_jobs`
    / telemetry work unchanged on top) PLUS the source-routing arrays
    the engine's source-routed mode consumes: ``route_port [M, H]``
    (output port to take at hop h of message m; ``PORT_EJECT`` = -1 at
    the terminal router) and ``vc_base [M]`` (the entry's VC class; the
    engine assigns ``min(vc_base + hops, V - 1)`` per hop);
  - :meth:`Policy.check_deadlock_free` validates the path set under
    that CLAMPED VC assignment via the channel-dependency-graph check
    (`repro.core.routing`) and raises :class:`PolicyDeadlockError`
    with the offending configuration spelled out when the CDG closes a
    cycle — wired into `repro.dist.collectives.emit_policy` so no
    deadlocking schedule reaches the engine.

Emission from collective algorithms lives in
`repro.dist.collectives.emit_policy`; schedule search over policies in
`repro.sim.workloads.search`.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ...core.routing import is_deadlock_free
from ..packed import HOPS_MAX
from ..tables import SimTables
from .ir import Workload

__all__ = ["PORT_EJECT", "PolicyEntry", "Policy", "PolicyWorkload",
           "PolicyDeadlockError", "from_transfers"]

# route_port sentinel: "this router is the terminal hop — eject".  Also
# the pad value past a path's end (never indexed: the flit ejects at
# its terminal hop, and hop indices are clamped below H).
PORT_EJECT = -1


class PolicyDeadlockError(ValueError):
    """The policy's explicit paths close a channel-dependency cycle
    under the engine's clamped VC assignment."""


@dataclasses.dataclass(frozen=True)
class PolicyEntry:
    """One explicitly-routed transfer: fires when `src_rank` owns
    `chunk_id` (i.e. when every entry in `deps` has fully delivered)."""
    chunk_id: int
    src_rank: int
    dst_rank: int
    vc_class: int
    size_flits: int
    path: Tuple[int, ...]             # router sequence, src..dst inclusive
    deps: Tuple[int, ...] = ()        # entry ids delivered before this fires
    phase: int = 0                    # reporting label (Workload phase)


@dataclasses.dataclass
class Policy:
    """An explicit-path collective schedule over `n_ranks` logical ranks
    placed on the routers named by `router_of_rank`."""
    name: str
    n_ranks: int
    router_of_rank: np.ndarray        # [n_ranks] int
    entries: List[PolicyEntry]
    phase_names: Tuple[str, ...] = ("policy",)

    @property
    def n_entries(self) -> int:
        return len(self.entries)

    @property
    def max_path_len(self) -> int:
        return max(len(e.path) for e in self.entries)

    @property
    def total_flits(self) -> int:
        return sum(e.size_flits for e in self.entries)

    def validate(self, adj: Optional[np.ndarray] = None) -> None:
        """Structural checks; with `adj` also that every hop is a live
        link of the fabric the policy claims to route on."""
        ror = np.asarray(self.router_of_rank)
        assert ror.shape == (self.n_ranks,)
        for i, e in enumerate(self.entries):
            assert e.size_flits > 0, f"entry {i}: zero-flit transfer"
            assert e.vc_class >= 0, f"entry {i}: negative vc_class"
            assert 0 <= e.src_rank < self.n_ranks, (i, e.src_rank)
            assert 0 <= e.dst_rank < self.n_ranks, (i, e.dst_rank)
            assert e.src_rank != e.dst_rank, f"entry {i}: self-send"
            assert len(e.path) >= 1, f"entry {i}: empty path"
            assert e.path[0] == ror[e.src_rank], \
                f"entry {i}: path starts at router {e.path[0]}, but " \
                f"rank {e.src_rank} lives on router {ror[e.src_rank]}"
            assert e.path[-1] == ror[e.dst_rank], \
                f"entry {i}: path ends at router {e.path[-1]}, but " \
                f"rank {e.dst_rank} lives on router {ror[e.dst_rank]}"
            assert len(e.path) <= HOPS_MAX, \
                f"entry {i}: {len(e.path)}-router path overflows the " \
                f"packed hop counter ({HOPS_MAX})"
            for h in range(len(e.path) - 1):
                u, v = e.path[h], e.path[h + 1]
                assert u != v, f"entry {i}: self-loop hop at {u}"
                if adj is not None:
                    assert adj[u, v], \
                        f"entry {i}: hop {u} -> {v} is not a live link"
            for d in e.deps:
                assert 0 <= d < i, \
                    f"entry {i}: dep {d} not an earlier entry " \
                    f"(policies are listed in a topological order)"

    def vc_lists(self, vcs: int) -> List[List[int]]:
        """Per-entry hop VC lists under the ENGINE's assignment:
        ``min(vc_class + hop_index, vcs - 1)`` — the clamp is what can
        make long paths reuse a VC and close CDG cycles."""
        return [[min(e.vc_class + h, vcs - 1)
                 for h in range(len(e.path) - 1)]
                for e in self.entries]

    def check_deadlock_free(self, n_routers: int, vcs: int) -> None:
        """Raise :class:`PolicyDeadlockError` if the path set closes a
        channel-dependency cycle under `vcs` virtual channels."""
        paths = [list(e.path) for e in self.entries]
        if not is_deadlock_free(paths, n_routers,
                                vcs_of=self.vc_lists(vcs)):
            raise PolicyDeadlockError(
                f"policy {self.name!r}: the explicit path set closes a "
                f"channel-dependency cycle under {vcs} VCs with the "
                f"clamped hop-indexed assignment min(vc_class + hop, "
                f"{vcs - 1}); raise the VC count, shorten the paths, or "
                f"stagger vc_class so no (channel, VC) pair is revisited")

    # -- lowering to the engine ---------------------------------------------
    def lower(self, tables: SimTables,
              ep_of_rank: np.ndarray) -> "PolicyWorkload":
        """Lower to a :class:`PolicyWorkload` for `tables` with ranks
        placed at `ep_of_rank` (whose routers must match
        `router_of_rank` — the paths were built for that placement)."""
        assert tables.lanes == 1, "lower() takes single-lane tables"
        ep_of_rank = np.asarray(ep_of_rank, dtype=np.int32)
        assert ep_of_rank.shape == (self.n_ranks,)
        got = tables.ep_router[ep_of_rank]
        assert np.array_equal(got, np.asarray(self.router_of_rank)), \
            "ep_of_rank places ranks on different routers than the " \
            "policy's paths assume"

        # port_of: inverse of the (live) nbr table
        n, P = tables.n_routers, tables.P
        port_of = np.full((n, n), -1, dtype=np.int32)
        for r in range(n):
            for o in range(P):
                v = tables.nbr[r, o]
                if v >= 0:
                    port_of[r, v] = o

        M = self.n_entries
        H = self.max_path_len
        route_port = np.full((M, H), PORT_EJECT, dtype=np.int32)
        for m, e in enumerate(self.entries):
            for h in range(len(e.path) - 1):
                u, v = e.path[h], e.path[h + 1]
                o = port_of[u, v]
                assert o >= 0, \
                    f"entry {m}: hop {u} -> {v} is not a live link of " \
                    f"these tables (failed edge?)"
                route_port[m, h] = o
            # route_port[m, len(path)-1] stays PORT_EJECT: terminal hop

        wl = PolicyWorkload(
            name=self.name, n_ranks=self.n_ranks,
            src=np.array([e.src_rank for e in self.entries], np.int32),
            dst=np.array([e.dst_rank for e in self.entries], np.int32),
            size=np.array([e.size_flits for e in self.entries], np.int32),
            deps=[np.asarray(e.deps, dtype=np.int32)
                  for e in self.entries],
            phase=np.array([e.phase for e in self.entries], np.int32),
            phase_names=self.phase_names,
            route_port=route_port,
            vc_base=np.array([e.vc_class for e in self.entries],
                             np.int32),
            ep_of_rank=ep_of_rank,
            paths=tuple(e.path for e in self.entries))
        wl.validate()
        return wl


@dataclasses.dataclass
class PolicyWorkload(Workload):
    """A lowered Policy: a plain message-DAG (runs unchanged through the
    table-routed engine, `run_jobs`, telemetry and the report layer)
    plus the source-routing operands of the engine's source-routed mode
    and the placement its paths assume."""
    route_port: Optional[np.ndarray] = None   # [M, H] port at hop h (-1 eject)
    vc_base: Optional[np.ndarray] = None      # [M] VC class per message
    ep_of_rank: Optional[np.ndarray] = None   # [n_ranks] baked placement
    paths: Tuple[Tuple[int, ...], ...] = ()   # router sequences (reporting)

    @property
    def max_hops(self) -> int:
        return int(self.route_port.shape[1])

    def validate(self) -> None:
        super().validate()
        assert self.route_port is not None and self.vc_base is not None
        assert self.route_port.shape[0] == self.n_messages
        assert self.vc_base.shape == (self.n_messages,)
        assert self.ep_of_rank is not None


def from_transfers(name: str, n_ranks: int, router_of_rank: np.ndarray,
                   transfers: Sequence[tuple],
                   initial_owner: Sequence[Tuple[int, int]],
                   phase_names: Tuple[str, ...] = ("policy",)) -> Policy:
    """Build a Policy from raw CCL-style transfer tuples, deriving
    dependency triggers from chunk OWNERSHIP (the SNIPPETS.md #1
    semantics: an entry installed at (chunk, src) fires when src fully
    owns the chunk).

    transfers     : sequence of (chunk_id, src_rank, dst_rank,
                    vc_class, size_flits, path[, phase]) in schedule
                    order.
    initial_owner : (chunk_id, rank) pairs owned before any transfer.

    A transfer's deps become the earlier entries that deliver its chunk
    to its source; a source that never obtains the chunk is an error.
    """
    owned = set(tuple(x) for x in initial_owner)
    delivered_by: dict = {}           # (chunk, rank) -> entry id
    entries: List[PolicyEntry] = []
    for t in transfers:
        chunk, src, dst, vc, size, path = t[:6]
        phase = t[6] if len(t) > 6 else 0
        if (chunk, src) in owned:
            deps: Tuple[int, ...] = ()
        elif (chunk, src) in delivered_by:
            deps = (delivered_by[(chunk, src)],)
        else:
            raise ValueError(
                f"transfer {len(entries)}: source rank {src} never "
                f"owns chunk {chunk!r} (not an initial owner and no "
                f"earlier transfer delivers it)")
        eid = len(entries)
        entries.append(PolicyEntry(chunk, src, dst, vc, size,
                                   tuple(path), deps, phase))
        # first delivery wins: ownership is monotone
        delivered_by.setdefault((chunk, dst), eid)
    pol = Policy(name, n_ranks, np.asarray(router_of_rank), entries,
                 phase_names)
    pol.validate()
    return pol
