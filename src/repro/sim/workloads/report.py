"""Workload run reporting + analytic cross-validation (DESIGN.md §7).

`summarize` turns a :class:`WorkloadResult` into per-phase latency
histograms and fabric-level bandwidth; `fabric_crosscheck` re-scores
the same collective with `repro.dist.topology_aware.FabricModel` in
CYCLE units so the analytic alpha-beta-with-hops model and the
cycle-level simulator can be compared directly (the §V sim is the
ground truth; the FabricModel is the planning-time estimate used by
`benchmarks/topology_collectives.py` and the training stack).

Unit calibration: the simulator moves 1 flit per channel per cycle and
pays ~1 cycle per hop, so a FabricModel built with
``link_bandwidth=flit_bytes`` (bytes per "second" == one flit per
cycle), ``link_latency=1.0`` and ``alpha=1.0`` (one cycle of
per-message software turnaround) returns times in cycles for payloads
given in bytes = flits * flit_bytes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from ...core.topology import Topology
from ...dist.topology_aware import FabricModel
from ..engine import _cache_put
from ..telemetry import export
from .closed_loop import WorkloadResult
from .ir import Workload

__all__ = ["PhaseStats", "WorkloadReport", "summarize",
           "cycle_fabric_model", "fabric_crosscheck"]


@dataclasses.dataclass
class PhaseStats:
    name: str
    n_messages: int
    n_completed: int
    latency_mean: float               # start -> completion, cycles
    latency_p50: float
    latency_p99: float
    hist_counts: np.ndarray           # latency histogram over completed
    hist_edges: np.ndarray


@dataclasses.dataclass
class WorkloadReport:
    result: WorkloadResult
    phases: Tuple[PhaseStats, ...]
    achieved_bw_flits_per_cycle: float
    per_rank_flits: np.ndarray        # [n_ranks] flits sourced per rank

    def table(self) -> str:
        r = self.result
        lines = [
            f"workload   {r.name}",
            f"mode       {r.mode}  placement={r.placement}",
            f"ranks      {r.n_ranks}  messages={r.n_messages}  "
            f"flits={int(r.msg_size.sum())}",
            f"makespan   {r.makespan:.0f} cycles"
            + ("" if r.completed else "  (INCOMPLETE)"),
            f"achieved   {self.achieved_bw_flits_per_cycle:.2f} flits/cycle"
            + ("" if r.completed else
               f"  (delivered/cycles_run over {r.cycles_run} cycles; "
               f"run did not complete)"),
            f"{'phase':16s} {'msgs':>6s} {'mean':>8s} {'p50':>8s} "
            f"{'p99':>8s}",
        ]
        for ph in self.phases:
            lines.append(f"{ph.name:16s} {ph.n_messages:6d} "
                         f"{ph.latency_mean:8.1f} {ph.latency_p50:8.1f} "
                         f"{ph.latency_p99:8.1f}")
        if r.telemetry is not None and r.telemetry.counters is not None:
            lines.extend(export.telemetry_summary(r.telemetry.counters,
                                                  top=5))
        return "\n".join(lines)


def summarize(wl: Workload, result: WorkloadResult,
              n_bins: int = 16) -> WorkloadReport:
    lat = (result.msg_done - result.msg_start).astype(np.float64)
    ok = result.msg_done >= 0
    # every phase is histogrammed over ONE shared set of edges spanning
    # all completed messages of the run, so per-phase counts are
    # directly comparable bin-for-bin (per-phase auto ranges made
    # cross-phase comparison meaningless and degenerated when a phase's
    # latencies were all equal)
    all_vals = lat[ok]
    if all_vals.size:
        lo, hi = float(all_vals.min()), float(all_vals.max())
        if lo == hi:                   # constant-latency guard
            lo, hi = lo - 0.5, hi + 0.5
        edges = np.linspace(lo, hi, n_bins + 1)
    else:
        edges = np.linspace(0.0, 1.0, n_bins + 1)
    phases = []
    for pid, pname in enumerate(wl.phase_names):
        sel = (result.msg_phase == pid)
        got = sel & ok
        vals = lat[got]
        if vals.size:
            counts, _ = np.histogram(vals, bins=edges)
            stats = PhaseStats(
                pname, int(sel.sum()), int(got.sum()),
                float(vals.mean()), float(np.percentile(vals, 50)),
                float(np.percentile(vals, 99)), counts, edges)
        else:
            stats = PhaseStats(pname, int(sel.sum()), 0, float("nan"),
                               float("nan"), float("nan"),
                               np.zeros(n_bins, np.int64), edges)
        phases.append(stats)
    per_rank = np.zeros(wl.n_ranks, dtype=np.int64)
    np.add.at(per_rank, wl.src, result.msg_sent)
    return WorkloadReport(result, tuple(phases), result.achieved_bw,
                          per_rank)


# ---------------------------------------------------------------------------
# analytic cross-check
# ---------------------------------------------------------------------------

_FM_CACHE: dict = {}


def cycle_fabric_model(topo: Topology, flit_bytes: int = 256) -> FabricModel:
    """FabricModel calibrated to simulator cycle units (cached per
    topology: the bisection term runs a spectral partition)."""
    key = (id(topo), flit_bytes)
    hit = _FM_CACHE.get(key)
    if hit is not None and hit[0] is topo:
        return hit[1]
    fm = FabricModel(topo, link_bandwidth=float(flit_bytes),
                     link_latency=1.0, alpha=1.0)
    _cache_put(_FM_CACHE, key, (topo, fm))
    return fm


def fabric_crosscheck(topo: Topology, collective: str,
                      payload_flits: int, ep_of_rank: np.ndarray,
                      makespan_cycles: float,
                      flit_bytes: int = 256,
                      algorithm: str = "ring") -> Dict[str, float]:
    """Compare a measured collective makespan against the FabricModel.

    `payload_flits` is the per-participant payload in flits (for the
    ring builder that is k * chunk_flits); `ep_of_rank` doubles as the
    participant list IN RING ORDER, matching `FabricModel.ring_hops`
    semantics.  Returns the estimate (cycles), the measurement, and
    their ratio — `benchmarks/workloads_jct.py` and
    `tests/test_workloads.py` assert the ratio stays within 2x for ring
    all-reduce on Slim Fly.
    """
    fm = cycle_fabric_model(topo, flit_bytes)
    est = fm.estimate(collective, float(payload_flits) * flit_bytes,
                      ep_of_rank)
    est_cycles = est[algorithm].time_s        # cycle-calibrated units
    ratio = (float(makespan_cycles) / est_cycles if est_cycles > 0
             else float("inf"))
    return {
        "estimate_cycles": float(est_cycles),
        "measured_cycles": float(makespan_cycles),
        "ratio": float(ratio),
        "algorithm": algorithm,
        "best_algorithm": est["best"].algorithm,
    }
