"""Rank -> endpoint placement (DESIGN.md §7).

Endpoints follow the `repro.sim.tables` / `repro.core.layout`
convention: sorted by endpoint-router id, exactly `p` per router, so
endpoint `e` lives on router `ep_router[e]` and rack
`rack_of[ep_router[e]]`.  Schemes:

  - linear:  rank i -> endpoint i (fills routers in id order)
  - blocked: fill routers in RACK order (`repro.core.layout` rack
             assignment) — consecutive ranks share a router, then a
             rack; the locality-preserving scheduler placement
  - random:  seeded permutation — the fragmented-cluster worst case
  - spread:  round-robin across endpoint routers — maximum injection
             parallelism, minimum locality

With ``n_ranks == n_endpoints`` every scheme returns a total order
(permutation) of the fabric's endpoints; the multi-tenant job layer
(`repro.sim.workloads.jobs.place_jobs`) slices those orders into
per-job placements (pack -> linear, spread -> spread, rack-aware ->
blocked).
"""

from __future__ import annotations

import numpy as np

from ...core.layout import make_layout
from ..tables import SimTables

__all__ = ["place_ranks", "PLACEMENTS"]

PLACEMENTS = ("linear", "blocked", "random", "spread")


def place_ranks(tables: SimTables, n_ranks: int, scheme: str = "linear",
                seed: int = 0) -> np.ndarray:
    """Returns ep_of_rank [n_ranks] int32, injective into endpoints."""
    n_ep = tables.n_endpoints
    if n_ranks > n_ep:
        raise ValueError(f"{n_ranks} ranks > {n_ep} endpoints")
    p = tables.p

    if scheme == "linear":
        out = np.arange(n_ranks)
    elif scheme == "random":
        out = np.random.default_rng(seed).permutation(n_ep)[:n_ranks]
    elif scheme == "blocked":
        layout = make_layout(tables.topo)
        ep_routers = tables.ep_router[::p]              # [N_epr] sorted
        order = np.argsort(
            layout.rack_of[ep_routers] * len(ep_routers)
            + np.arange(len(ep_routers)), kind="stable")
        eps = (order[:, None] * p + np.arange(p)[None, :]).reshape(-1)
        out = eps[:n_ranks]
    elif scheme == "spread":
        n_epr = n_ep // p
        i = np.arange(n_ranks)
        out = (i % n_epr) * p + i // n_epr
    else:
        raise ValueError(f"unknown placement {scheme!r}; have {PLACEMENTS}")
    return out.astype(np.int32)
