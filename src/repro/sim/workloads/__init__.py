"""Closed-loop HPC workload engine on the flit simulator (DESIGN.md §7).

- ir:          message-DAG workload IR + builders (collectives, stencil,
               graph scatter)
- mapping:     logical rank -> endpoint placement schemes
- closed_loop: dependency-triggered flit injection on the shared
               SwitchCore; chunked lax.scan with early exit
- jobs:        multi-tenant Job layer: arrival cycles, pack/spread/
               rack-aware placement, FIFO/backfill admission queue,
               one closed-loop run over the concatenated job mix
- report:      makespan / per-phase latency / bandwidth + FabricModel
               cross-validation
"""

from .closed_loop import WorkloadResult, WorkloadSimConfig, run_workload
from .jobs import (
    JOB_PLACEMENTS,
    QUEUE_POLICIES,
    Job,
    JobResult,
    MultiJobResult,
    place_jobs,
    run_jobs,
)
from .ir import (
    Workload,
    all_to_all,
    graph_scatter,
    make_workload,
    recursive_doubling_all_reduce,
    ring_all_reduce,
    stencil,
)
from .mapping import PLACEMENTS, place_ranks
from .report import (
    WorkloadReport,
    cycle_fabric_model,
    fabric_crosscheck,
    summarize,
)

__all__ = [
    "Workload",
    "ring_all_reduce",
    "recursive_doubling_all_reduce",
    "all_to_all",
    "stencil",
    "graph_scatter",
    "make_workload",
    "PLACEMENTS",
    "place_ranks",
    "WorkloadSimConfig",
    "WorkloadResult",
    "run_workload",
    "Job",
    "JobResult",
    "MultiJobResult",
    "JOB_PLACEMENTS",
    "QUEUE_POLICIES",
    "place_jobs",
    "run_jobs",
    "WorkloadReport",
    "summarize",
    "cycle_fabric_model",
    "fabric_crosscheck",
]
