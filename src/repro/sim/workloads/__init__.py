"""Closed-loop HPC workload engine on the flit simulator (DESIGN.md §7).

- ir:          message-DAG workload IR + builders (collectives, stencil,
               graph scatter)
- mapping:     logical rank -> endpoint placement schemes
- closed_loop: dependency-triggered flit injection on the shared
               SwitchCore; chunked lax.scan with early exit
- report:      makespan / per-phase latency / bandwidth + FabricModel
               cross-validation
"""

from .closed_loop import WorkloadResult, WorkloadSimConfig, run_workload
from .ir import (
    Workload,
    all_to_all,
    graph_scatter,
    make_workload,
    recursive_doubling_all_reduce,
    ring_all_reduce,
    stencil,
)
from .mapping import PLACEMENTS, place_ranks
from .report import (
    WorkloadReport,
    cycle_fabric_model,
    fabric_crosscheck,
    summarize,
)

__all__ = [
    "Workload",
    "ring_all_reduce",
    "recursive_doubling_all_reduce",
    "all_to_all",
    "stencil",
    "graph_scatter",
    "make_workload",
    "PLACEMENTS",
    "place_ranks",
    "WorkloadSimConfig",
    "WorkloadResult",
    "run_workload",
    "WorkloadReport",
    "summarize",
    "cycle_fabric_model",
    "fabric_crosscheck",
]
