"""Closed-loop HPC workload engine on the flit simulator (DESIGN.md §7).

- ir:          message-DAG workload IR + builders (collectives, stencil,
               graph scatter)
- policy:      explicit-path collective policy IR (DESIGN.md §13):
               chunked, dependency-triggered, explicitly-routed
               transfers; lowers to a PolicyWorkload the engine runs
               source-routed
- mapping:     logical rank -> endpoint placement schemes
- closed_loop: dependency-triggered flit injection on the shared
               SwitchCore; chunked lax.scan with early exit
- jobs:        multi-tenant Job layer: arrival cycles (fixed or
               Poisson-sampled), pack/spread/rack-aware placement,
               FIFO/backfill admission queue, one closed-loop run over
               the concatenated job mix
- search:      schedule search: lane-batched scoring of candidate
               policies + a local-search driver
- report:      makespan / per-phase latency / bandwidth + FabricModel
               cross-validation
"""

from .closed_loop import WorkloadResult, WorkloadSimConfig, run_workload
from .jobs import (
    ARRIVALS,
    JOB_PLACEMENTS,
    QUEUE_POLICIES,
    Job,
    JobResult,
    MultiJobResult,
    place_jobs,
    poisson_arrivals,
    run_jobs,
    with_arrivals,
)
from .ir import (
    Workload,
    all_to_all,
    graph_scatter,
    make_workload,
    recursive_doubling_all_reduce,
    ring_all_gather,
    ring_all_reduce,
    ring_reduce_scatter,
    stencil,
)
from .mapping import PLACEMENTS, place_ranks
from .policy import (
    Policy,
    PolicyDeadlockError,
    PolicyEntry,
    PolicyWorkload,
    from_transfers,
)
from .search import Genome, SearchResult, local_search, search_config
from .report import (
    WorkloadReport,
    cycle_fabric_model,
    fabric_crosscheck,
    summarize,
)

__all__ = [
    "Workload",
    "ring_all_reduce",
    "ring_reduce_scatter",
    "ring_all_gather",
    "recursive_doubling_all_reduce",
    "all_to_all",
    "stencil",
    "graph_scatter",
    "make_workload",
    "Policy",
    "PolicyEntry",
    "PolicyWorkload",
    "PolicyDeadlockError",
    "from_transfers",
    "Genome",
    "SearchResult",
    "local_search",
    "search_config",
    "PLACEMENTS",
    "place_ranks",
    "WorkloadSimConfig",
    "WorkloadResult",
    "run_workload",
    "Job",
    "JobResult",
    "MultiJobResult",
    "JOB_PLACEMENTS",
    "QUEUE_POLICIES",
    "ARRIVALS",
    "place_jobs",
    "run_jobs",
    "poisson_arrivals",
    "with_arrivals",
    "WorkloadReport",
    "summarize",
    "cycle_fabric_model",
    "fabric_crosscheck",
]
