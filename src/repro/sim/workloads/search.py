"""Schedule search over explicit-path collective policies (DESIGN.md
§13).

The closed loop the policy IR exists for: `emit_policy`
(repro.dist.collectives) turns a collective into a candidate schedule,
`Policy.lower` turns it into engine operands, and
`sweep_run_policies` (repro.sim.sweep) scores a WHOLE GENERATION of
candidates in one compiled lane-batched run — chunk count, path-set
choice, path seed and entry ordering vary per lane as traced operands,
so a generation of L schedules costs one device launch and (with
`pad_to` pinned, as here) the entire search costs ONE compile.

`local_search` is a deliberately small hill-climber over the genome

    (n_chunks, path_set, path_seed, order_seed)

seeded with the canonical baselines (the unchunked MIN-path ring
schedule among them, so the best-found result can never lose to the
ring baseline it is compared against).  It is a demonstration that the
simulator can OPTIMISE schedules, not just replay them; plug richer
genomes or search strategies into `score_genomes` for more.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ...core.routing import UNREACH, RoutingTables
from ..tables import SimTables
from .closed_loop import WorkloadSimConfig, _sweep_run_policies
from .mapping import place_ranks

__all__ = ["Genome", "ScoredGenome", "SearchResult", "search_config",
           "score_genomes", "local_search"]


@dataclasses.dataclass(frozen=True)
class Genome:
    """One candidate schedule's emission parameters."""
    n_chunks: int = 1
    path_set: str = "min"             # "min" | "diverse"
    path_seed: int = 0
    order_seed: Optional[int] = None  # None = builder order

    def label(self) -> str:
        o = "-" if self.order_seed is None else str(self.order_seed)
        return (f"nc{self.n_chunks}/{self.path_set}"
                f"/p{self.path_seed}/o{o}")


@dataclasses.dataclass
class ScoredGenome:
    genome: Genome
    makespan: float                   # cycles (inf = didn't complete)
    flits: int


@dataclasses.dataclass
class SearchResult:
    kind: str
    n_ranks: int
    best: ScoredGenome
    baseline: ScoredGenome            # unchunked MIN schedule (= ring)
    history: List[ScoredGenome]       # every candidate ever scored
    n_scored: int
    n_generations: int
    lanes_per_generation: int
    elapsed_s: float

    @property
    def speedup(self) -> float:
        """Baseline / best makespan (>= 1 by construction)."""
        return float(self.baseline.makespan / self.best.makespan)

    @property
    def schedules_per_sec(self) -> float:
        return self.n_scored / max(self.elapsed_s, 1e-9)


def search_config(**kw) -> WorkloadSimConfig:
    """The search's engine config: source-routed MIN (the policy's own
    paths route every flit)."""
    kw.setdefault("routing", "source")
    kw.setdefault("mode", "min")
    return WorkloadSimConfig(**kw)


def _emit(kind: str, rt: RoutingTables, n_ranks: int, size_flits: int,
          router_of_rank: np.ndarray, g: Genome, vcs: int):
    from ...dist.collectives import emit_policy
    return emit_policy(kind, rt, n_ranks, size_flits, router_of_rank,
                       n_chunks=g.n_chunks, path_set=g.path_set,
                       path_seed=g.path_seed, order_seed=g.order_seed,
                       vcs=vcs)


def _pad_shapes(tables: SimTables, rt: RoutingTables, kind: str,
                n_ranks: int, size_flits: int,
                router_of_rank: np.ndarray, ep_of_rank: np.ndarray,
                max_chunks: int, vcs: int) -> Tuple[int, int, int, int]:
    """Search-wide operand shapes, from the largest genome the search
    can emit: max_chunks chunks per message and any minimal path.  One
    compiled executable then scores EVERY generation."""
    big = _emit(kind, rt, n_ranks, size_flits, router_of_rank,
                Genome(n_chunks=max_chunks), vcs).lower(tables, ep_of_rank)
    d = rt.dist[rt.dist < UNREACH]
    hmax = int(d.max()) + 1 if d.size else 1
    src_ep = big.ep_of_rank[big.src]
    kmax = int(np.bincount(src_ep,
                           minlength=tables.n_endpoints).max())
    return (big.n_messages, big.dep_matrix().shape[1], kmax,
            max(big.route_port.shape[1], hmax))


def score_genomes(tables: SimTables, rt: RoutingTables, kind: str,
                  n_ranks: int, size_flits: int,
                  genomes: Sequence[Genome],
                  ep_of_rank: np.ndarray, cfg: WorkloadSimConfig,
                  pad_to: Tuple[int, int, int, int]) -> List[ScoredGenome]:
    """Emit + lower + score one generation in a single lane-batched
    run.  Returns ScoredGenomes in input order."""
    router_of_rank = tables.ep_router[ep_of_rank].astype(np.int64)
    wls = [_emit(kind, rt, n_ranks, size_flits, router_of_rank, g,
                 cfg.vcs).lower(tables, ep_of_rank) for g in genomes]
    res = _sweep_run_policies(tables, wls, cfg, pad_to=pad_to)
    return [ScoredGenome(g, r.makespan, r.flits_delivered)
            for g, r in zip(genomes, res)]


def _mutations(best: Genome, rng, n: int, max_chunks: int) -> List[Genome]:
    """n random single-step tweaks of `best` plus fresh random genomes."""
    out = []
    while len(out) < n:
        k = int(rng.integers(4))
        g = best if int(rng.integers(2)) else Genome(
            n_chunks=int(rng.integers(1, max_chunks + 1)),
            path_set=("min", "diverse")[int(rng.integers(2))],
            path_seed=int(rng.integers(1 << 16)),
            order_seed=(None, int(rng.integers(1 << 16)))[
                int(rng.integers(2))])
        if k == 0:
            g = dataclasses.replace(
                g, n_chunks=int(rng.integers(1, max_chunks + 1)))
        elif k == 1:
            g = dataclasses.replace(
                g, path_set=("min", "diverse")[int(rng.integers(2))],
                path_seed=int(rng.integers(1 << 16)))
        elif k == 2:
            g = dataclasses.replace(g, path_seed=int(rng.integers(1 << 16)))
        else:
            g = dataclasses.replace(
                g, order_seed=(None, int(rng.integers(1 << 16)))[
                    int(rng.integers(2))])
        out.append(g)
    return out


def local_search(tables: SimTables, rt: RoutingTables, kind: str,
                 n_ranks: int, size_flits: int,
                 cfg: Optional[WorkloadSimConfig] = None,
                 ep_of_rank: Optional[np.ndarray] = None,
                 generations: int = 3, lanes: int = 8,
                 max_chunks: int = 4, seed: int = 0) -> SearchResult:
    """Hill-climb over collective schedules, one lane-batched compile
    per search (`lanes` candidates scored per generation).

    Generation 0 holds the canonical baselines — the unchunked MIN
    schedule (the ring baseline for ring kinds), its chunked variants,
    and diverse-path seeds; later generations mutate the incumbent.
    The baseline rides in every comparison, so `best.makespan <=
    baseline.makespan` always holds.
    """
    assert lanes >= 2 and generations >= 1
    cfg = cfg or search_config()
    assert cfg.routing == "source", "schedule search scores explicit paths"
    if ep_of_rank is None:
        ep_of_rank = place_ranks(tables, n_ranks, cfg.placement,
                                 seed=cfg.seed)
    ep_of_rank = np.asarray(ep_of_rank, dtype=np.int32)
    router_of_rank = tables.ep_router[ep_of_rank].astype(np.int64)
    pad_to = _pad_shapes(tables, rt, kind, n_ranks, size_flits,
                         router_of_rank, ep_of_rank, max_chunks, cfg.vcs)
    rng = np.random.default_rng(seed)

    t0 = time.perf_counter()
    base = Genome()                                  # nc=1, MIN, in order
    gen0 = [base,
            Genome(n_chunks=min(2, max_chunks)),
            Genome(n_chunks=max_chunks),
            Genome(path_set="diverse", path_seed=1),
            Genome(n_chunks=max_chunks, path_set="diverse", path_seed=2),
            Genome(n_chunks=min(2, max_chunks), path_set="diverse",
                   path_seed=3)]
    gen0 = gen0[:lanes] + _mutations(base, rng, lanes - min(lanes, len(gen0)),
                                     max_chunks)

    history: List[ScoredGenome] = []
    seen = set()

    def run_gen(genomes):
        fresh = []
        for g in genomes:
            if g not in seen:
                seen.add(g)
                fresh.append(g)
        if not fresh:
            return
        history.extend(score_genomes(tables, rt, kind, n_ranks,
                                     size_flits, fresh, ep_of_rank, cfg,
                                     pad_to))

    run_gen(gen0)
    baseline = next(s for s in history if s.genome == base)
    for _ in range(generations - 1):
        best = min(history, key=lambda s: s.makespan)
        run_gen(_mutations(best.genome, rng, lanes, max_chunks))
    elapsed = time.perf_counter() - t0

    best = min(history, key=lambda s: s.makespan)
    return SearchResult(
        kind=kind, n_ranks=n_ranks, best=best, baseline=baseline,
        history=history, n_scored=len(history),
        n_generations=generations, lanes_per_generation=lanes,
        elapsed_s=elapsed)
