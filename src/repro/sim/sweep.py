"""Lane-batched sweep engine (DESIGN.md §10).

Every figure in the paper is a *sweep*: latency/throughput vs injected
load (Fig 6), resiliency metrics vs failure fraction (Table III),
workload JCT vs routing mode.  Run sequentially, a sweep pays a Python
round-trip per point — and, when the points differ by a failure mask,
a full XLA recompile per point, because the single-lane runners bake
the mask-dependent tables into the trace as constants (deliberately:
XLA specialises the per-cycle gathers against them, DESIGN.md §10).
Here, and only here, the tables of mask-varying lanes are lifted into
traced OPERANDS, so one compile serves every mask.

This module stacks L sweep points that differ only in DATA (injection
rate, PRNG seed, failure edge-mask / degraded tables) into a leading
*lane* axis and runs them as ONE jax.vmap-ed scan: one trace, one
compile, one device launch for the whole sweep.  Anything that changes
SHAPE or the traced graph — topology, routing mode, cycle count, VC
count, kernel path — still (necessarily) forces its own compile and
must be equal across lanes.

Lane semantics are exact: per-lane results are bit-identical to L
sequential `simulate` / `run_workload` calls with the same configs
(tests/test_sweep.py) because jax.vmap maps every primitive — including
the allocation kernels, whose pallas grids grow a trailing lane
dimension under batching — without changing per-lane values.

  - `sweep_simulate`: open-loop Bernoulli engine over (rate, seed,
    tables) lanes -> [SimResult per lane];
  - `sweep_run_workload`: closed-loop workload engine over (seed,
    tables) lanes -> [WorkloadResult per lane]; the chunked host loop
    early-exits when EVERY lane has completed (completed lanes idle
    inertly: all messages sent and drained, counters guarded);
  - L == 1 degenerates to the exact single-lane code path
    (`simulate` / `run_workload`), so callers can sweep
    unconditionally.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from . import telemetry as tel
from .engine import (SimConfig, SimResult, SwitchCore, _assemble_result,
                     _cache_put, _open_loop_step, simulate,
                     tables_signature)
from .tables import SimTables
from .traffic import Traffic

__all__ = ["sweep_simulate", "sweep_run_workload", "sweep_run_policies",
           "lane_tables"]

TablesLanes = Union[SimTables, Sequence[SimTables]]


def lane_tables(tables: TablesLanes) -> SimTables:
    """Normalise a tables argument to one (possibly stacked) SimTables."""
    if isinstance(tables, SimTables):
        return tables
    tables = list(tables)
    if len(tables) == 1:
        return tables[0]
    return SimTables.stack(tables)


def _lane_count(name_and_lens: list) -> int:
    """Infer L from per-argument lane counts; 1 broadcasts, anything
    else must agree exactly (the ragged-lane guard)."""
    L = 1
    for name, n in name_and_lens:
        if n == 1:
            continue
        if L == 1:
            L = n
        elif n != L:
            ragged = {name: n for name, n in name_and_lens}
            raise ValueError(
                f"ragged lanes: {ragged} — lane-varying arguments must "
                f"all have the same length (or length 1 to broadcast)")
    return L


def _as_list(x, scalar_types) -> list:
    if x is None:
        return [None]
    if isinstance(x, scalar_types):
        return [x]
    return list(x)


# sweep-runner cache, FIFO-bounded alongside the engine's.  Two key
# regimes: lanes sharing one table set keep it as closure constants
# (same gather specialisation as the single-lane path) and key by
# table identity; mask-varying sweeps lift the tables into traced
# operands and key STRUCTURALLY (tables_signature), so every set of
# failure samples of one topology reuses one executable.
_SWEEP_CACHE: dict = {}


def _sweep_runner(tables0: SimTables, traffic: Traffic, cfg: SimConfig,
                  L: int, tables_vary: bool):
    tab_key = (tables_signature(tables0) if tables_vary
               else id(tables0))
    key = (tab_key, id(traffic), cfg.static_key(), L, tables_vary)
    hit = _SWEEP_CACHE.get(key)
    if hit is not None and hit[0] is traffic and \
            (tables_vary or hit[1] is tables0):
        return hit[2]

    core = SwitchCore(tables0, cfg)

    def scan_lane(c, carry, rate):
        step = _open_loop_step(c, traffic, rate)
        cycles = jnp.arange(cfg.cycles, dtype=jnp.int32)
        return jax.lax.scan(step, carry, cycles)

    if tables_vary:
        # per-lane masks: tables ride the lane axis as operands
        def run_lane(table_ops, carry, rate):
            return scan_lane(core.bind_tables(table_ops), carry, rate)

        table_axes = jax.tree_util.tree_map(lambda _: 0,
                                            core.table_operands())
        fn = jax.jit(jax.vmap(run_lane, in_axes=(table_axes, 0, 0)),
                     donate_argnums=(1,))
    else:
        # shared tables: keep them as constants (XLA specialises the
        # per-cycle gathers; the lane vmap batches only the state)
        def run_shared(carry, rate):
            return scan_lane(core, carry, rate)

        fn = jax.jit(jax.vmap(run_shared, in_axes=(0, 0)),
                     donate_argnums=(0,))
    _cache_put(_SWEEP_CACHE, key, (traffic, tables0, (core, fn)))
    return core, fn


def sweep_simulate(tables: TablesLanes, traffic: Traffic, cfg: SimConfig,
                   rates: Optional[Sequence[float]] = None,
                   seeds: Optional[Sequence[int]] = None) -> list:
    """Run L open-loop simulations as one compiled, lane-batched scan.

    tables : SimTables, stacked SimTables, or a list of same-shape
             SimTables (e.g. per-failure-sample rebuilds); a single
             table set is shared by every lane.
    rates  : per-lane injection rates (default: cfg.injection_rate).
    seeds  : per-lane PRNG seeds (default: cfg.seed).

    Length-1 arguments broadcast to L; mismatched lengths raise
    (ragged-lane guard).  Returns [SimResult] * L, bit-identical per
    lane to the sequential `simulate` loop.
    """
    tab = lane_tables(tables)
    rates_l = _as_list(rates, (int, float, np.integer, np.floating))
    seeds_l = _as_list(seeds, (int, np.integer))
    L = _lane_count([("tables", tab.lanes), ("rates", len(rates_l)),
                     ("seeds", len(seeds_l))])

    rates_l = [cfg.injection_rate if r is None else float(r)
               for r in rates_l] * (L if len(rates_l) == 1 else 1)
    seeds_l = [cfg.seed if s is None else int(s)
               for s in seeds_l] * (L if len(seeds_l) == 1 else 1)
    cfgs = [dataclasses.replace(cfg, injection_rate=rates_l[i],
                                seed=seeds_l[i]) for i in range(L)]

    if L == 1:
        # degenerate sweep: exactly today's single-lane path
        return [simulate(tab.lane(0), traffic, cfgs[0])]

    tables_vary = tab.lanes > 1
    core, fn = _sweep_runner(tab.lane(0), traffic, cfg, L,
                             tables_vary=tables_vary)

    carry0 = tuple(jnp.zeros((L,) + q.shape, q.dtype)
                   for q in core.init_queues())
    keys0 = jnp.stack([jax.random.PRNGKey(s) for s in seeds_l])
    # the telemetry element is part of the lane-mapped carry: counters
    # are pure data-parallel accumulators (no scatters besides the
    # trace ring), so per-lane telemetry comes out of the SAME compile
    tel0 = jax.tree_util.tree_map(
        lambda a: jnp.zeros((L,) + a.shape, a.dtype),
        tel.init_state(cfg.telemetry, core))
    carry0 = carry0 + (keys0, tel0)
    rate_v = jnp.asarray(rates_l, jnp.float32)

    if tables_vary:
        # the stacked mask tables ride the lane axis as one operand
        carry, stats = fn(SwitchCore.device_tables(tab), carry0, rate_v)
    else:
        carry, stats = fn(carry0, rate_v)

    n_active = int(traffic.active.sum())
    out = []
    for i in range(L):
        lane_stats = tuple(np.asarray(s)[i] for s in stats)
        snap = tel.snapshot(
            cfg.telemetry,
            jax.tree_util.tree_map(lambda a: a[i], carry[5]),
            cfg.cycles)
        out.append(_assemble_result(tab.lane(i if tab.lanes > 1 else 0),
                                    traffic, cfgs[i], n_active, lane_stats,
                                    snap))
    return out


def sweep_run_workload(tables: TablesLanes, wl, cfg=None,
                       seeds: Optional[Sequence[int]] = None,
                       ep_of_rank: Optional[np.ndarray] = None) -> list:
    """Closed-loop analogue of `sweep_simulate`: run workload `wl` on L
    (tables, seed) lanes in one compiled chunk loop.

    The chunked host loop runs until EVERY lane has completed (or
    cfg.max_cycles); per-lane makespans and message stats are
    bit-identical to sequential `run_workload` calls.  Returns
    [WorkloadResult] * L.

    Lanes vary data only: the sweep runs the single-job (J=1,
    admitted-at-cycle-0) degenerate of the multi-job engine — the job
    mix and placement shape the traced step and must stay
    lane-invariant (DESIGN.md §10/§11).
    """
    # local import: workloads imports the engine (avoid a cycle)
    from .workloads import closed_loop

    return closed_loop._sweep_run_workload(
        lane_tables(tables), wl, cfg, seeds=seeds, ep_of_rank=ep_of_rank)


def sweep_run_policies(tables: SimTables, wls, cfg=None,
                       pad_to=None) -> list:
    """Score L candidate SCHEDULES (lowered PolicyWorkloads) in one
    lane-batched source-routed run (DESIGN.md §13).

    The inverse lane split of `sweep_run_workload`: the topology is
    fixed (tables stay closure constants) and the WORKLOAD arrays —
    sizes, deps, explicit paths, VC classes, per-endpoint order,
    placement — vary per lane as traced operands.  Candidates are
    padded to common shapes; pass `pad_to=(M, dmax, kmax, hmax)` to pin
    the shapes across generations so one compiled executable scores an
    entire schedule search.  Returns [WorkloadResult] * L, bit-identical
    per lane to sequential `run_workload(routing='source')` calls.
    """
    from .workloads import closed_loop

    return closed_loop._sweep_run_policies(lane_tables(tables), wls, cfg,
                                           pad_to=pad_to)
