"""Cycle-based single-flit network simulator in JAX (paper §V).

- tables:  topology -> dense JAX routing/port tables
- traffic: §V traffic patterns (uniform, shuffle, bit ops, shift,
           SF worst-case, DF worst-case)
- engine:  input-queued router model, lax.scan over cycles
"""

from .engine import SimConfig, SimResult, simulate
from .tables import SimTables
from .traffic import make_traffic

__all__ = ["SimConfig", "SimResult", "simulate", "SimTables", "make_traffic"]
