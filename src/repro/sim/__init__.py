"""Cycle-based flit network simulator in JAX (paper §V).

- tables:    topology -> dense JAX routing/port tables
- traffic:   §V traffic patterns (uniform, shuffle, bit ops, shift,
             SF worst-case, DF worst-case)
- engine:    input-queued router model (SwitchCore), lax.scan over
             cycles; open-loop Bernoulli `simulate`
- sweep:     lane-batched sweeps — L (rate, seed, failure-mask) points
             as one compiled vmap-ed scan (DESIGN.md §10)
- workloads: closed-loop message-DAG engine on the same SwitchCore
             (collectives / stencil / graph JCT runs, DESIGN.md §7)
"""

from .engine import SimConfig, SimResult, SwitchCore, simulate
from .sweep import sweep_run_workload, sweep_simulate
from .tables import SimTables
from .traffic import make_traffic

__all__ = ["SimConfig", "SimResult", "SwitchCore", "simulate", "SimTables",
           "make_traffic", "sweep_simulate", "sweep_run_workload"]
