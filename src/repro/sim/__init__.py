"""Cycle-based flit network simulator in JAX (paper §V).

- tables:    topology -> dense JAX routing/port tables
- traffic:   §V traffic patterns (uniform, shuffle, bit ops, shift,
             SF worst-case, DF worst-case)
- engine:    input-queued router model (SwitchCore), lax.scan over
             cycles; open-loop Bernoulli `simulate`
- sweep:     lane-batched sweeps — L (rate, seed, failure-mask) points
             as one compiled vmap-ed scan (DESIGN.md §10)
- workloads: closed-loop message-DAG engine on the same SwitchCore
             (collectives / stencil / graph JCT runs, DESIGN.md §7)
- telemetry: opt-in in-scan counters + flit-sampled tracing threaded
             through both engines' scan carries, with heatmap and
             perfetto/Chrome-trace export (DESIGN.md §12)
"""

from .engine import SimConfig, SimResult, SwitchCore, simulate
from .sweep import sweep_run_workload, sweep_simulate
from .tables import SimTables
from .telemetry import TelemetryConfig, TelemetrySnapshot
from .traffic import make_traffic

__all__ = ["SimConfig", "SimResult", "SwitchCore", "simulate", "SimTables",
           "make_traffic", "sweep_simulate", "sweep_run_workload",
           "TelemetryConfig", "TelemetrySnapshot"]
