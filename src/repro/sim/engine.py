"""Cycle-based flit network simulator (paper §V), fully vectorized in
JAX with a lax.scan over cycles.

Model (faithful to the paper's setup):
  - single-flit packets, Bernoulli injection (§V), input-queued routers;
  - V virtual channels per input port, hop-indexed VC assignment (§IV-D)
    => deadlock-free by construction (verified by tests/test_routing.py);
  - per-cycle pipeline: route -> switch allocation -> link traversal;
  - switch allocation: rotating-priority matching over a lookahead window
    of W packets per input queue (W rounds of maximal matching).  This is
    the vectorized stand-in for Booksim's internal speedup 2 + iSLIP —
    without it an input-queued router caps at ~59% throughput from
    head-of-line blocking (cf. DESIGN.md §5);
  - one packet per output channel per cycle (channel rate 1 flit/cycle);
  - backpressure: a packet advances only if the downstream input queue for
    (port, VC) has a free slot (credit view);
  - ejection capacity p packets/router/cycle (one per endpoint downlink);
  - routing modes: 'min', 'val', 'ugal_l', 'ugal_g' (§IV), and 'ecmp'
    (adaptive equal-cost next-hop — the FT-3 ANCA stand-in).

The switch itself (credit view, per-flit route choice, W-round
allocation, window compaction) lives in :class:`SwitchCore` and is
shared between two engines that differ only in how source queues fill
and in what they fold over ejection grants:

  - `simulate` (this module): open-loop Bernoulli injection, the §V
    latency/throughput methodology;
  - `repro.sim.workloads.closed_loop`: dependency-triggered multi-flit
    message injection for closed-loop workload (JCT) runs; its packet
    records carry a sixth MSG field that the core passes through
    untouched.

State layout: packet records are int32 [..., F] with fields (dst_router,
inter, inject_cycle, hops, phase[, msg]).  Network queues [N, P, V, Qn,
F] as circular FIFOs with (head, count); source queues [N_ep, Qs, F].

`simulate` compiles one `(rate, key) ->` scan per (tables, traffic,
static-config) signature and caches it, so a load sweep (fig6) traces
and compiles the network exactly once — injection rate and PRNG seed are
traced operands, not Python constants baked into the graph.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.routing import UNREACH
from .tables import SimTables
from .traffic import Traffic

__all__ = ["SimConfig", "SimResult", "SwitchCore", "simulate"]

DST, INTER, TIME, HOPS, PHASE, MSG = range(6)
BIG = jnp.int32(1 << 30)
# occupancy values entering UGAL scores are clamped here so that the
# dead-port sentinel (occupancy() returns BIG for nbr < 0) cannot
# overflow int32 when multiplied by a path length, while still dwarfing
# any real queue depth (degraded fabrics, DESIGN.md §8)
OCC_CAP = jnp.int32(1 << 20)


@dataclasses.dataclass(frozen=True)
class SimConfig:
    injection_rate: float = 0.2       # packets / endpoint / cycle
    cycles: int = 2000
    warmup: int = 500
    vcs: int = 4                      # paper sims use 3; adaptive needs 4
    q_net: int = 16                   # per-(port,VC) buffer (64 flits/port @ 4 VC)
    q_src: int = 64
    mode: str = "min"                 # min | val | ugal_l | ugal_g | ecmp
    n_val_candidates: int = 4         # §IV-C: 4 works best
    lookahead: int = 4                # allocation window (HOL mitigation)
    seed: int = 0

    def static_key(self) -> tuple:
        """Fields that shape the compiled graph (rate/seed are traced)."""
        return (self.cycles, self.vcs, self.q_net, self.q_src, self.mode,
                self.n_val_candidates, self.lookahead)


@dataclasses.dataclass
class SimResult:
    name: str
    offered_load: float
    accepted_load: float              # delivered / cycle / active endpoint
    avg_latency: float                # cycles, measurement window
    delivered: int
    injected: int
    dropped_at_source: int
    src_occupancy: float              # mean source-queue depth (saturation)
    per_cycle_delivered: np.ndarray
    # end-of-cycle snapshots for the flit-conservation invariant
    # (tests/test_sim.py): cumsum(injected) == cumsum(delivered) +
    # in_flight at EVERY cycle prefix; dropped packets never enter the
    # network (refused at a full source queue).
    per_cycle_injected: np.ndarray = None
    per_cycle_in_flight: np.ndarray = None
    per_cycle_dropped: np.ndarray = None

    @property
    def saturated(self) -> bool:
        return self.src_occupancy > 0.5 * 64 or self.dropped_at_source > 0


class SwitchCore:
    """Shared input-queued switch pipeline for one (tables, config).

    Owns the device-resident routing tables and implements the four
    engine-independent stages of a cycle: credit-view `occupancy`,
    per-flit `route_decision`, and `alloc` (W rounds of
    rotating-priority matching with immediate arrivals, followed by
    window compaction and dequeues).  Engines inject into the source
    queues themselves and pass an `eject_fold(acc, grant_ej, req_pkt,
    cycle)` callback so open-loop stats (delivered/latency) and
    closed-loop stats (per-message flit counts) use the same matching
    machinery.  `n_fields` is the packet record width: 5 for open-loop,
    6 (with a trailing MSG id) for closed-loop; the core only
    interprets fields 0..4 and carries the rest verbatim.
    """

    def __init__(self, tables: SimTables, cfg: SimConfig,
                 n_fields: int = 5):
        self.tables = tables
        self.F = n_fields
        N, P, V = tables.n_routers, tables.P, cfg.vcs
        self.N, self.P, self.V = N, P, V
        self.Qn, self.Qs = cfg.q_net, cfg.q_src
        self.n_ep = tables.n_endpoints
        self.p = tables.p
        self.W = cfg.lookahead
        self.mode = cfg.mode
        self.C = cfg.n_val_candidates

        self.nbr = jnp.asarray(tables.nbr)
        self.rev_port = jnp.asarray(tables.rev_port)
        self.port_toward = jnp.asarray(tables.port_toward)
        self.dist = jnp.asarray(tables.dist.astype(np.int32))
        self.ep_router = jnp.asarray(tables.ep_router)
        self.has_ecmp = tables.ecmp_ports is not None
        self.ecmp_ports = (jnp.asarray(tables.ecmp_ports)
                           if self.has_ecmp else None)

        # endpoint-router blocks for ejection ranking: endpoints are
        # sorted by router and each endpoint-router has exactly p
        # endpoints.
        self.ep_block_router = jnp.asarray(tables.ep_router[::self.p])
        self.n_epr = self.n_ep // self.p

        self.unreach = jnp.int32(int(UNREACH))

        self.NQ = N * P * V
        self.R = self.NQ + self.n_ep
        self.eids = jnp.arange(self.n_ep)
        self.routers_n = jnp.arange(N)[:, None, None]          # [N,1,1]
        self.req_r_const = jnp.concatenate(
            [jnp.broadcast_to(self.routers_n, (N, P, V)).reshape(-1),
             self.ep_router])

    # -- queue state ---------------------------------------------------------
    def init_queues(self) -> tuple:
        """(nq_pkt, nq_head, nq_count, sq_pkt, sq_head, sq_count) zeros."""
        N, P, V, Qn, Qs, F = (self.N, self.P, self.V, self.Qn, self.Qs,
                              self.F)
        return (jnp.zeros((N, P, V, Qn, F), jnp.int32),
                jnp.zeros((N, P, V), jnp.int32),
                jnp.zeros((N, P, V), jnp.int32),
                jnp.zeros((self.n_ep, Qs, F), jnp.int32),
                jnp.zeros((self.n_ep,), jnp.int32),
                jnp.zeros((self.n_ep,), jnp.int32))

    def occupancy(self, nq_count):
        """Credit view: occ[r, o] = downstream input-queue depth."""
        safe_nbr = jnp.maximum(self.nbr, 0)
        safe_rev = jnp.maximum(self.rev_port, 0)
        occ = nq_count[safe_nbr, safe_rev, :].sum(-1)          # [N, P]
        return jnp.where(self.nbr >= 0, occ, BIG)

    def inject(self, sq_pkt, sq_head, sq_count, want, new_pkt):
        """Masked tail enqueue into the per-endpoint source FIFOs.

        `want` must already account for backpressure (`sq_count < Qs`);
        both engines share these mechanics by construction.
        """
        tail = (sq_head + sq_count) % self.Qs
        cur = sq_pkt[self.eids, tail]
        sq_pkt = sq_pkt.at[self.eids, tail].set(
            jnp.where(want[:, None], new_pkt, cur))
        return sq_pkt, sq_count + want.astype(jnp.int32)

    # -- routing -------------------------------------------------------------
    def route_decision(self, dst_r, occ, key):
        """Per-endpoint injection-time path choice -> (inter, phase)."""
        mode, C, N, n_ep = self.mode, self.C, self.N, self.n_ep
        src_r = self.ep_router
        dist, port_toward, nbr = self.dist, self.port_toward, self.nbr
        if mode in ("min", "ecmp"):
            return dst_r, jnp.ones_like(dst_r)
        if mode == "val":
            i = jax.random.randint(key, (n_ep,), 0, N)
            for bump in (1, 1):
                bad = (i == src_r) | (i == dst_r)
                i = jnp.where(bad, (i + bump) % N, i)
            # degraded fabrics: only detour via intermediates that can
            # still reach both endpoints; dead draws fall back to MIN
            live = (dist[src_r, i] + dist[i, dst_r]) < self.unreach
            return (jnp.where(live, i, dst_r),
                    (~live).astype(jnp.int32))

        # UGAL: score MIN vs C random VAL candidates (live ones only)
        cands = jax.random.randint(key, (n_ep, C), 0, N)
        for bump in (1, 2):
            bad = (cands == src_r[:, None]) | (cands == dst_r[:, None])
            cands = jnp.where(bad, (cands + bump) % N, cands)

        def first_occ(s, t):
            o = port_toward[s, t]
            return jnp.where(o >= 0,
                             jnp.minimum(occ[s, jnp.maximum(o, 0)], OCC_CAP),
                             0)

        def path_occ(s, t):
            """Occupancy sum along the MIN path (D <= 2 fast form)."""
            o1 = port_toward[s, t]
            m = nbr[s, jnp.maximum(o1, 0)]
            two = dist[s, t] >= 2
            second = jnp.where(two, first_occ(m, t), 0)
            return first_occ(s, t) + second

        len_min = dist[src_r, dst_r]                              # [n_ep]
        len_val = dist[src_r[:, None], cands] + dist[cands, dst_r[:, None]]
        live_min = len_min < self.unreach
        live_val = len_val < self.unreach
        if mode == "ugal_l":
            score_min = len_min * first_occ(src_r, dst_r)
            score_val = len_val * first_occ(src_r[:, None], cands)
        else:  # ugal_g: smallest sum of queues along the whole path
            score_min = path_occ(src_r, dst_r) + len_min
            score_val = (path_occ(src_r[:, None], cands)
                         + path_occ(cands, dst_r[:, None]) + len_val)
        score_min = jnp.where(live_min, score_min, BIG)
        score_val = jnp.where(live_val, score_val, BIG)

        scores = jnp.concatenate([score_min[:, None], score_val], axis=1)
        inters = jnp.concatenate([dst_r[:, None], cands], axis=1)
        best = jnp.argmin(scores, axis=1)                         # MIN wins ties
        inter = jnp.take_along_axis(inters, best[:, None], 1)[:, 0]
        phase = (best == 0).astype(jnp.int32)                     # MIN: phase 1
        return inter, phase

    # -- allocation ----------------------------------------------------------
    def _desires(self, pkt, router, occ):
        tgt = jnp.where(pkt[..., PHASE] == 1, pkt[..., DST],
                        pkt[..., INTER])
        eject = (pkt[..., DST] == router) & (pkt[..., PHASE] == 1)
        min_port = self.port_toward[router, tgt]
        if self.has_ecmp:
            # dead alternates are skipped automatically: occupancy() is
            # BIG where nbr < 0, so argmin lands on a live port
            opts = self.ecmp_ports[router, tgt]                   # [..., M]
            r_b = jnp.broadcast_to(router[..., None], opts.shape)
            o_occ = jnp.where(opts >= 0,
                              occ[r_b, jnp.maximum(opts, 0)], BIG)
            pick = jnp.argmin(o_occ, axis=-1)
            ecmp_port = jnp.take_along_axis(opts, pick[..., None],
                                            -1)[..., 0]
            if self.mode == "ecmp":
                out_port = ecmp_port
            else:
                # MIN first; equal-cost alternate only when the MIN
                # port is dead (transient failure mask on tables whose
                # routes have not re-converged, DESIGN.md §8)
                min_dead = ((min_port >= 0)
                            & (self.nbr[router,
                                        jnp.maximum(min_port, 0)] < 0))
                out_port = jnp.where(min_dead, ecmp_port, min_port)
            out_port = jnp.where(eject, -1, out_port)
        else:
            out_port = min_port
        out_vc = jnp.minimum(pkt[..., HOPS], self.V - 1)
        return out_port, out_vc, eject

    def alloc(self, nq_pkt, nq_head, nq_count, sq_pkt, sq_head, sq_count,
              occ, cycle, eject_fold: Callable, eject_acc):
        """One cycle of W-round switch allocation + compaction.

        Returns the six queue arrays plus the folded ejection
        accumulator.  `eject_fold(acc, grant_ej [R] bool, req_pkt
        [R, F], cycle)` is called once per round with that round's
        ejection grants.
        """
        N, P, V, Qn, Qs, F, W = (self.N, self.P, self.V, self.Qn,
                                 self.Qs, self.F, self.W)
        NQ, R, n_ep, p, n_epr = self.NQ, self.R, self.n_ep, self.p, self.n_epr
        nbr, rev_port = self.nbr, self.rev_port
        eids, ep_router = self.eids, self.ep_router
        ep_block_router, req_r_const = self.ep_block_router, self.req_r_const

        queue_granted = jnp.zeros((R,), bool)
        grant_slot = jnp.full((R,), -1, jnp.int32)
        chan_taken = jnp.zeros((N * P,), bool)
        ej_budget = jnp.full((N,), p, jnp.int32)
        pending_cnt = nq_count  # grows with this cycle's arrivals

        for w in range(W):
            nh_w = jnp.take_along_axis(
                nq_pkt, ((nq_head + w) % Qn)[:, :, :, None, None],
                axis=3)[:, :, :, 0]                                # [N,P,V,F]
            n_valid = (nq_count > w) & (nbr[:, :, None] >= 0)
            sh_w = sq_pkt[eids, (sq_head + w) % Qs]
            s_valid = sq_count > w

            n_out, n_vc, n_ej = self._desires(
                nh_w, jnp.broadcast_to(self.routers_n, (N, P, V)), occ)
            s_out, s_vc, s_ej = self._desires(sh_w, ep_router, occ)

            req_out = jnp.concatenate([n_out.reshape(-1), s_out])
            req_vc = jnp.concatenate([n_vc.reshape(-1), s_vc])
            req_ej = jnp.concatenate([n_ej.reshape(-1), s_ej])
            req_valid = (jnp.concatenate([n_valid.reshape(-1), s_valid])
                         & ~queue_granted)
            req_pkt = jnp.concatenate([nh_w.reshape(-1, F), sh_w], axis=0)

            # --- ejection grants against remaining per-router budget
            ej = req_valid & req_ej
            ej_net = ej[:NQ].reshape(N, P * V)
            ej_src = ej[NQ:].reshape(n_epr, p)
            shift = cycle % (P * V)
            rolled = jnp.roll(ej_net, -shift, axis=1)
            rank_net = jnp.roll(jnp.cumsum(rolled, axis=1) - 1, shift, axis=1)
            net_total = ej_net.sum(axis=1).astype(jnp.int32)
            rank_src = jnp.cumsum(ej_src, axis=1) - 1
            net_first = (cycle % 2) == 0
            src_total = jnp.zeros((N,), jnp.int32).at[ep_block_router].add(
                ej_src.sum(axis=1).astype(jnp.int32))
            rank_net_f = rank_net + jnp.where(net_first, 0,
                                              src_total[:, None])
            rank_src_f = rank_src + jnp.where(
                net_first, net_total[ep_block_router], 0)[:, None]
            g_net = ej_net & (rank_net_f < ej_budget[:, None])
            g_src = ej_src & (rank_src_f < ej_budget[ep_block_router][:, None])
            grant_ej = jnp.concatenate([g_net.reshape(-1), g_src.reshape(-1)])
            ej_budget = ej_budget - g_net.sum(axis=1).astype(jnp.int32)
            ej_budget = ej_budget.at[ep_block_router].add(
                -g_src.sum(axis=1).astype(jnp.int32))

            # --- network channel grants
            down_r = nbr[req_r_const, jnp.maximum(req_out, 0)]
            down_port = rev_port[req_r_const, jnp.maximum(req_out, 0)]
            space = pending_cnt[jnp.maximum(down_r, 0),
                                jnp.maximum(down_port, 0), req_vc] < Qn
            keys_seg = req_r_const * P + jnp.maximum(req_out, 0)
            eligible = (req_valid & ~req_ej & (req_out >= 0) & (down_r >= 0)
                        & space & ~chan_taken[keys_seg])
            qidx = jnp.arange(R)
            rot = (qidx + cycle * 7919 + w * 131) % R
            score = jnp.where(eligible, rot * R + qidx,
                              jnp.iinfo(jnp.int32).max)
            seg_min = jax.ops.segment_min(score, keys_seg, num_segments=N * P)
            winner = eligible & (score == seg_min[keys_seg])

            chan_taken = chan_taken.at[keys_seg].max(winner)
            granted_now = winner | grant_ej
            queue_granted = queue_granted | granted_now
            grant_slot = jnp.where(granted_now & (grant_slot < 0), w,
                                   grant_slot)

            # --- apply arrivals immediately (unique (router, port) / cycle)
            arr_pkt = req_pkt.at[:, HOPS].add(1)
            arr_pkt = arr_pkt.at[:, PHASE].set(
                jnp.where(down_r == arr_pkt[:, INTER], 1, arr_pkt[:, PHASE]))
            a_r = jnp.where(winner, down_r, N)          # OOB => dropped write
            a_p = jnp.maximum(down_port, 0)
            a_tail = (nq_head[jnp.minimum(a_r, N - 1), a_p, req_vc]
                      + pending_cnt[jnp.minimum(a_r, N - 1), a_p,
                                    req_vc]) % Qn
            nq_pkt = nq_pkt.at[a_r, a_p, req_vc, a_tail].set(
                arr_pkt, mode="drop")
            pending_cnt = pending_cnt.at[a_r, a_p, req_vc].add(
                winner.astype(jnp.int32), mode="drop")

            # --- engine-specific ejection stats
            eject_acc = eject_fold(eject_acc, grant_ej, req_pkt, cycle)

        # ---- dequeues: remove packet at offset grant_slot (shift-up) -----
        g_net = grant_slot[:NQ].reshape(N, P, V)
        g_src = grant_slot[NQ:]
        for j in range(W - 1, 0, -1):
            # slot head+j <- slot head+j-1 where grant_slot >= j
            m_net = (g_net >= j)
            src_slot = jnp.take_along_axis(
                nq_pkt, ((nq_head + j - 1) % Qn)[:, :, :, None, None],
                axis=3)[:, :, :, 0]
            dst_idx = ((nq_head + j) % Qn)
            cur = jnp.take_along_axis(
                nq_pkt, dst_idx[:, :, :, None, None], axis=3)[:, :, :, 0]
            newv = jnp.where(m_net[..., None], src_slot, cur)
            nq_pkt = jax.vmap(
                lambda q, i, v: q.at[i].set(v),
                in_axes=(0, 0, 0))(
                    nq_pkt.reshape(NQ, Qn, F), dst_idx.reshape(NQ),
                    newv.reshape(NQ, F)).reshape(N, P, V, Qn, F)
            m_src = (g_src >= j)
            s_from = sq_pkt[eids, (sq_head + j - 1) % Qs]
            s_didx = (sq_head + j) % Qs
            s_cur = sq_pkt[eids, s_didx]
            sq_pkt = sq_pkt.at[eids, s_didx].set(
                jnp.where(m_src[:, None], s_from, s_cur))

        deq_net = (g_net >= 0).astype(jnp.int32)
        deq_src = (g_src >= 0).astype(jnp.int32)
        nq_head = (nq_head + deq_net) % Qn
        nq_count = pending_cnt - deq_net
        sq_head = (sq_head + deq_src) % Qs
        sq_count = sq_count - deq_src

        return (nq_pkt, nq_head, nq_count, sq_pkt, sq_head, sq_count,
                eject_acc)


def _open_loop_fold(acc, grant_ej, req_pkt, cycle):
    """Open-loop ejection stats: delivered count + latency sum."""
    delivered, lat_sum = acc
    delivered = delivered + grant_ej.sum().astype(jnp.int32)
    lat_sum = lat_sum + jnp.where(
        grant_ej, cycle - req_pkt[:, TIME] + 1, 0).sum().astype(jnp.float32)
    return delivered, lat_sum


# (tables, traffic, static-config) -> compiled (rate, key) -> per-cycle
# stats.  Values pin the tables/traffic objects so the id() keys cannot
# be silently reused by the allocator; the FIFO bound keeps a long-lived
# process from accumulating compiled executables without limit.
_OPEN_LOOP_CACHE: dict = {}
_CACHE_MAX = 32


def _cache_put(cache: dict, key, value) -> None:
    while len(cache) >= _CACHE_MAX:
        cache.pop(next(iter(cache)))
    cache[key] = value


def _open_loop_runner(tables: SimTables, traffic: Traffic, cfg: SimConfig):
    key = (id(tables), id(traffic), cfg.static_key())
    hit = _OPEN_LOOP_CACHE.get(key)
    if hit is not None and hit[0] is tables and hit[1] is traffic:
        return hit[2]

    core = SwitchCore(tables, cfg, n_fields=5)
    active = jnp.asarray(traffic.active)
    n_ep, Qs = core.n_ep, core.Qs
    sample = traffic.sample

    def run(rate, key0):
        def step(carry, cycle):
            (nq_pkt, nq_head, nq_count, sq_pkt, sq_head, sq_count,
             key) = carry
            key, k_inj, k_dst, k_rt = jax.random.split(key, 4)

            occ = core.occupancy(nq_count)

            # ---- injection ------------------------------------------------
            coin = jax.random.bernoulli(k_inj, rate, (n_ep,)) & active
            want = coin & (sq_count < Qs)
            dropped = (coin & (sq_count >= Qs)).sum()
            dst_ep = sample(k_dst)
            dst_r = core.ep_router[dst_ep]
            inter, phase = core.route_decision(dst_r, occ, k_rt)
            new_pkt = jnp.stack(
                [dst_r, inter, jnp.full((n_ep,), cycle, jnp.int32),
                 jnp.zeros((n_ep,), jnp.int32), phase], axis=-1)
            sq_pkt, sq_count = core.inject(sq_pkt, sq_head, sq_count,
                                           want, new_pkt)
            injected = want.sum()

            # ---- shared switch pipeline -----------------------------------
            (nq_pkt, nq_head, nq_count, sq_pkt, sq_head, sq_count,
             (delivered, lat_sum)) = core.alloc(
                 nq_pkt, nq_head, nq_count, sq_pkt, sq_head, sq_count,
                 occ, cycle, _open_loop_fold,
                 (jnp.int32(0), jnp.float32(0.0)))

            in_flight = (nq_count.sum() + sq_count.sum()).astype(jnp.int32)
            stats = (injected.astype(jnp.int32), delivered,
                     lat_sum, sq_count.sum().astype(jnp.int32),
                     dropped.astype(jnp.int32), in_flight)
            return (nq_pkt, nq_head, nq_count, sq_pkt, sq_head, sq_count,
                    key), stats

        carry = core.init_queues() + (key0,)
        cycles = jnp.arange(cfg.cycles, dtype=jnp.int32)
        _, stats = jax.lax.scan(step, carry, cycles)
        return stats

    fn = jax.jit(run)
    _cache_put(_OPEN_LOOP_CACHE, key, (tables, traffic, fn))
    return fn


def simulate(tables: SimTables, traffic: Traffic, cfg: SimConfig) -> SimResult:
    n_active = int(traffic.active.sum())
    run = _open_loop_runner(tables, traffic, cfg)
    inj, dlv, lat, occ_s, drop, infl = run(
        jnp.float32(cfg.injection_rate), jax.random.PRNGKey(cfg.seed))

    inj = np.asarray(inj, dtype=np.int64)
    dlv = np.asarray(dlv, dtype=np.int64)
    lat = np.asarray(lat, dtype=np.float64)
    occ_s = np.asarray(occ_s, dtype=np.float64)
    drop = np.asarray(drop, dtype=np.int64)
    infl = np.asarray(infl, dtype=np.int64)

    n_ep = tables.n_endpoints
    w = cfg.warmup
    meas = slice(w, cfg.cycles)
    m_cycles = cfg.cycles - w
    delivered_m = int(dlv[meas].sum())
    accepted = delivered_m / (m_cycles * max(n_active, 1))
    avg_lat = float(lat[meas].sum() / max(delivered_m, 1))
    return SimResult(
        name=f"{traffic.name}-{cfg.mode}",
        offered_load=cfg.injection_rate,
        accepted_load=float(accepted),
        avg_latency=avg_lat,
        delivered=int(dlv.sum()),
        injected=int(inj.sum()),
        dropped_at_source=int(drop.sum()),
        src_occupancy=float(occ_s[meas].mean() / max(n_ep, 1)),
        per_cycle_delivered=dlv,
        per_cycle_injected=inj,
        per_cycle_in_flight=infl,
        per_cycle_dropped=drop,
    )
