"""Cycle-based flit network simulator (paper §V), fully vectorized in
JAX with a lax.scan over cycles.

Model (faithful to the paper's setup):
  - single-flit packets, Bernoulli injection (§V), input-queued routers;
  - V virtual channels per input port, hop-indexed VC assignment (§IV-D)
    => deadlock-free by construction (verified by tests/test_routing.py);
  - per-cycle pipeline: route -> switch allocation -> link traversal;
  - switch allocation: rotating-priority matching over a lookahead window
    of W packets per input queue (W rounds of maximal matching).  This is
    the vectorized stand-in for Booksim's internal speedup 2 + iSLIP —
    without it an input-queued router caps at ~59% throughput from
    head-of-line blocking (cf. DESIGN.md §5);
  - one packet per output channel per cycle (channel rate 1 flit/cycle);
  - backpressure: a packet advances only if the downstream input queue for
    (port, VC) has a free slot (credit view);
  - ejection capacity p packets/router/cycle (one per endpoint downlink);
  - routing modes: 'min', 'val', 'ugal_l', 'ugal_g' (§IV), and 'ecmp'
    (adaptive equal-cost next-hop — the FT-3 ANCA stand-in).

The switch itself (credit view, per-flit route choice, W-round
allocation, window compaction) lives in :class:`SwitchCore` and is
shared between two engines that differ only in how source queues fill
and in what they fold over ejection grants:

  - `simulate` (this module): open-loop Bernoulli injection, the §V
    latency/throughput methodology;
  - `repro.sim.workloads.closed_loop`: dependency-triggered multi-flit
    message injection for closed-loop workload (JCT) runs; its packet
    records carry an extra bit-packed MSG field that the core passes
    through untouched.

Paper-scale hot path (DESIGN.md §9).  Queue state is bit-packed
(`repro.sim.packed`): every flit record is 3 int32 words and the big
routing tables are int16 on device.  A cycle gathers ONE W-slot window
of every queue up front, computes route desires for all W slots at
once, and hands the router-local conflict resolution to
`repro.kernels.alloc_rounds` (Pallas kernel or its bit-identical jnp
oracle, selected by ``SimConfig.kernel_path``); UGAL/VAL candidate
scoring likewise runs through `repro.kernels.ugal_select`.  Two
engine-level identities make the single-gather structure exact (the
grants are bit-identical to a per-round re-gather):

  1. arrivals land at offsets >= the cycle-start queue depth, and a
     window slot is only valid below that depth — this cycle's
     arrivals can never be granted this cycle;
  2. a downstream input queue (router, port) receives at most one
     packet per cycle, always via its unique upstream channel, and
     `chan_taken` blocks that channel after its win — so the
     backpressure (space) check against cycle-start depths is exact.

State layout: packed records [..., PK=3]; network queues [N, P, V, Qn,
PK] as shift-down FIFOs (head at slot 0) with a count array; source
queues [N_ep, Qs, PK].

`simulate` compiles one `(carry, rate) ->` scan per (tables, traffic,
static-config) signature and caches it: injection rate and PRNG seed
are traced operands, so a load sweep (fig6) traces and compiles the
network exactly once.  The routing tables stay CLOSURE CONSTANTS here
— XLA specialises the per-cycle gathers against constant index tables
(~2.5x at q=11) — so a new failure mask recompiles this path; sweeps
over masks belong on the lane-batched engine (`repro.sim.sweep`),
where the tables become traced operands shared by one compile across
all lanes (DESIGN.md §10).  The initial scan carry is donated.
"""

from __future__ import annotations

import copy
import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.routing import UNREACH
from ..kernels import alloc_rounds, ugal_select
from . import telemetry as tel
from .packed import (MAX_ROUTERS, PK, bump_hops_word, pack_record, pk_dst,
                     pk_hops, pk_inter, pk_msg, pk_phase, pk_time)
from .tables import SimTables
from .telemetry import TelemetryConfig, TelemetrySnapshot
from .traffic import Traffic

__all__ = ["SimConfig", "SimResult", "SwitchCore", "simulate",
           "TelemetryConfig"]

BIG = jnp.int32(1 << 30)
# occupancy values entering UGAL scores are clamped here so that the
# dead-port sentinel (occupancy() returns BIG for nbr < 0) cannot
# overflow int32 when multiplied by a path length, while still dwarfing
# any real queue depth (degraded fabrics, DESIGN.md §8)
OCC_CAP = jnp.int32(1 << 20)


@dataclasses.dataclass(frozen=True)
class SimConfig:
    injection_rate: float = 0.2       # packets / endpoint / cycle
    cycles: int = 2000
    warmup: int = 500
    vcs: int = 4                      # paper sims use 3; adaptive needs 4
    q_net: int = 16                   # per-(port,VC) buffer (64 flits/port @ 4 VC)
    q_src: int = 64
    mode: str = "min"                 # min | val | ugal_l | ugal_g | ecmp
    n_val_candidates: int = 4         # §IV-C: 4 works best
    lookahead: int = 4                # allocation window (HOL mitigation)
    seed: int = 0
    # hot-path implementation: 'auto' = Pallas kernels on TPU, jnp
    # oracles elsewhere; 'ref' / 'pallas' force a path (the kernels are
    # bit-identical — tests/test_engine_scaling.py)
    kernel_path: str = "auto"
    # opt-in counters/tracing threaded through the scan carry
    # (repro.sim.telemetry); the default is fully off and adds ZERO
    # carry leaves — bit-exact vs a build without the layer
    telemetry: TelemetryConfig = TelemetryConfig()

    def static_key(self) -> tuple:
        """Fields that shape the compiled graph (rate/seed are traced)."""
        return (self.cycles, self.vcs, self.q_net, self.q_src, self.mode,
                self.n_val_candidates, self.lookahead, self.kernel_path,
                self.telemetry.static_key())


@dataclasses.dataclass
class SimResult:
    name: str
    offered_load: float
    accepted_load: float              # delivered / cycle / active endpoint
    avg_latency: float                # cycles, measurement window
    delivered: int
    injected: int
    dropped_at_source: int
    src_occupancy: float              # mean source-queue depth (saturation)
    per_cycle_delivered: np.ndarray
    # end-of-cycle snapshots for the flit-conservation invariant
    # (tests/test_sim.py): cumsum(injected) == cumsum(delivered) +
    # in_flight at EVERY cycle prefix; dropped packets never enter the
    # network (refused at a full source queue).
    per_cycle_injected: Optional[np.ndarray] = None
    per_cycle_in_flight: Optional[np.ndarray] = None
    per_cycle_dropped: Optional[np.ndarray] = None
    # the configured source-queue depth, so `saturated` scales with the
    # run's actual backlog capacity instead of a hard-coded 64
    q_src: int = 64
    telemetry: Optional[TelemetrySnapshot] = None

    @property
    def saturated(self) -> bool:
        return (self.src_occupancy > 0.5 * self.q_src
                or self.dropped_at_source > 0)


class SwitchCore:
    """Shared input-queued switch pipeline for one (tables, config).

    Owns the device-resident routing tables and implements the four
    engine-independent stages of a cycle: credit-view `occupancy`,
    per-flit `route_decision`, and `alloc` (W rounds of
    rotating-priority matching with immediate arrivals, followed by
    window compaction and dequeues).  Engines inject into the source
    queues themselves and pass an `eject_fold(acc, grant_net [N,P,V]
    bool, grant_src [n_ep] bool, pkt_net [N,P,V,PK], pkt_src [n_ep,PK],
    cycle)` callback, called once per allocation round with that
    round's ejection grants and the (packed) granted head-window
    records, so open-loop stats (delivered/latency) and closed-loop
    stats (per-message flit counts) use the same matching machinery.
    The fold reads fields through `repro.sim.packed` accessors — no
    concat or unpack boundary sits on the hot path.
    """

    def __init__(self, tables: SimTables, cfg: SimConfig):
        assert tables.lanes == 1, \
            "SwitchCore is single-lane; stacked tables go to sim.sweep"
        self.tables = tables
        N, P, V = tables.n_routers, tables.P, cfg.vcs
        assert N < MAX_ROUTERS, f"router ids overflow packed records: {N}"
        self.N, self.P, self.V = N, P, V
        self.Qn, self.Qs = cfg.q_net, cfg.q_src
        self.n_ep = tables.n_endpoints
        self.p = int(tables.p)
        self.W = cfg.lookahead
        self.mode = cfg.mode
        self.C = cfg.n_val_candidates
        self.tel = cfg.telemetry
        kp = cfg.kernel_path
        assert kp in ("auto", "ref", "pallas"), kp
        self.use_pallas = (kp == "pallas"
                           or (kp == "auto"
                               and jax.default_backend() == "tpu"))
        # table-routed by default; bind_source_routes switches a copy
        # into source-routed mode (explicit per-message paths)
        self.src_route = None
        self.src_to_gid = None

        # narrow on-device tables (DESIGN.md §9): the O(N^2) tables are
        # int16 (ids < 2^15 asserted above) and gathered values are
        # widened to int32 at their use sites
        self.ecmp_ports = None
        for name, arr in self.device_tables(tables).items():
            setattr(self, name, arr)
        self.has_ecmp = tables.ecmp_ports is not None
        self.ep_router = jnp.asarray(tables.ep_router.astype(np.int32))

        # endpoint-router blocks for ejection ranking: endpoints are
        # sorted by router and each endpoint-router has exactly p
        # endpoints.
        ebr = tables.ep_router[::self.p].astype(np.int32)
        self.ep_block_router = jnp.asarray(ebr)
        self.n_epr = self.n_ep // self.p
        epr_index = np.full((N,), -1, dtype=np.int32)
        epr_index[ebr] = np.arange(self.n_epr, dtype=np.int32)
        self.epr_index = jnp.asarray(epr_index)

        self.unreach = jnp.int32(int(UNREACH))

        self.NQ = N * P * V
        self.R = self.NQ + self.n_ep
        self.eids = jnp.arange(self.n_ep)
        self.routers_n = jnp.arange(N)[:, None, None]          # [N,1,1]

    # -- table operands ------------------------------------------------------
    # Routing tables are TRACED OPERANDS of the compiled step, not
    # closure constants: with constants, every failure mask bakes a
    # different HLO (so each degraded fabric recompiles and the
    # persistent compilation cache can never hit), and the sweep
    # engine could not vmap over per-lane masks at all (DESIGN.md §10).
    @staticmethod
    def device_tables(tables: SimTables) -> dict:
        """The mask-dependent table arrays, as device operands."""
        ops = {
            "nbr": jnp.asarray(tables.nbr.astype(np.int32)),
            "rev_port": jnp.asarray(tables.rev_port.astype(np.int32)),
            "port_toward": jnp.asarray(tables.port_toward.astype(np.int16)),
            "dist": jnp.asarray(tables.dist.astype(np.int16)),
        }
        if tables.ecmp_ports is not None:
            ops["ecmp_ports"] = jnp.asarray(
                tables.ecmp_ports.astype(np.int16))
        return ops

    def table_operands(self) -> dict:
        """This core's current table arrays (pass back via bind_tables)."""
        ops = {"nbr": self.nbr, "rev_port": self.rev_port,
               "port_toward": self.port_toward, "dist": self.dist}
        if self.has_ecmp:
            ops["ecmp_ports"] = self.ecmp_ports
        return ops

    def bind_tables(self, ops: dict) -> "SwitchCore":
        """Shallow copy with the table arrays swapped for `ops` (tracers
        inside a jit/vmap, or another mask's concrete arrays)."""
        assert ("ecmp_ports" in ops) == self.has_ecmp
        c = copy.copy(self)
        for name, arr in ops.items():
            setattr(c, name, arr)
        return c

    def bind_source_routes(self, route_port, vc_base,
                           to_gid=None) -> "SwitchCore":
        """Shallow copy in SOURCE-ROUTED mode (DESIGN.md §13).

        `route_port [M, H]` gives the output port message m takes at
        hop h (indexed by the packed hop counter); a negative entry
        means "this router is the terminal hop — eject".  `vc_base [M]`
        is the message's VC class: hop h rides VC
        ``min(vc_base + h, V - 1)``.  `to_gid` maps the packed MSG
        field to a route_port row (identity when message ids are
        global).  Route choice from the routing tables is bypassed
        entirely; occupancy/credits, W-round allocation, compaction and
        ejection machinery are unchanged.  Both arrays may be closure
        constants (single-lane) or traced operands (the schedule-search
        lane sweep, which varies them per lane)."""
        c = copy.copy(self)
        c.src_route = (route_port, vc_base)
        c.src_to_gid = to_gid if to_gid is not None else (lambda f: f)
        return c

    # -- queue state ---------------------------------------------------------
    # Queues are shift-down FIFOs: the head packet always sits at slot 0
    # and slots 0..count-1 are occupied, so the W-slot allocation window
    # is a STATIC slice and dequeue+compaction is a static-shift select
    # — no circular-head gathers or scatters anywhere on the flit
    # arrays (DESIGN.md §9).  The abstract queue sequence is identical
    # to the seed's circular FIFOs, so grants are bit-identical.
    def init_queues(self) -> tuple:
        """(nq_pkt, nq_count, sq_pkt, sq_count) zeros."""
        N, P, V, Qn, Qs = self.N, self.P, self.V, self.Qn, self.Qs
        return (jnp.zeros((N, P, V, Qn, PK), jnp.int32),
                jnp.zeros((N, P, V), jnp.int32),
                jnp.zeros((self.n_ep, Qs, PK), jnp.int32),
                jnp.zeros((self.n_ep,), jnp.int32))

    def occupancy(self, nq_count):
        """Credit view: occ[r, o] = downstream input-queue depth."""
        safe_nbr = jnp.maximum(self.nbr, 0)
        safe_rev = jnp.maximum(self.rev_port, 0)
        occ = nq_count[safe_nbr, safe_rev, :].sum(-1)          # [N, P]
        return jnp.where(self.nbr >= 0, occ, BIG)

    def inject(self, sq_pkt, sq_count, want, new_pkt):
        """Masked tail enqueue into the per-endpoint source FIFOs.

        `want` must already account for backpressure (`sq_count < Qs`);
        both engines share these mechanics by construction.  Masked
        dense write: XLA CPU scatters serialise per row, a [n_ep, Qs]
        select does not (DESIGN.md §9).
        """
        ins = want[:, None] & (jnp.arange(self.Qs) == sq_count[:, None])
        sq_pkt = jnp.where(ins[..., None], new_pkt[:, None, :], sq_pkt)
        return sq_pkt, sq_count + want.astype(jnp.int32)

    # -- routing -------------------------------------------------------------
    def _dist32(self, s, t):
        return self.dist[s, t].astype(jnp.int32)

    def route_decision(self, dst_r, occ, key):
        """Per-endpoint injection-time path choice -> (inter, phase)."""
        mode, C, N, n_ep = self.mode, self.C, self.N, self.n_ep
        src_r = self.ep_router
        port_toward, nbr = self.port_toward, self.nbr
        if mode in ("min", "ecmp"):
            return dst_r, jnp.ones_like(dst_r)
        if mode == "val":
            i = jax.random.randint(key, (n_ep,), 0, N)
            for bump in (1, 1):
                bad = (i == src_r) | (i == dst_r)
                i = jnp.where(bad, (i + bump) % N, i)
            # degraded fabrics: only detour via intermediates that can
            # still reach both endpoints; dead draws fall back to MIN
            live = (self._dist32(src_r, i)
                    + self._dist32(i, dst_r)) < self.unreach
            return (jnp.where(live, i, dst_r),
                    (~live).astype(jnp.int32))

        # UGAL: score MIN vs C random VAL candidates (live ones only)
        cands = jax.random.randint(key, (n_ep, C), 0, N)
        for bump in (1, 2):
            bad = (cands == src_r[:, None]) | (cands == dst_r[:, None])
            cands = jnp.where(bad, (cands + bump) % N, cands)

        def first_occ(s, t):
            o = port_toward[s, t].astype(jnp.int32)
            return jnp.where(o >= 0,
                             jnp.minimum(occ[s, jnp.maximum(o, 0)], OCC_CAP),
                             0)

        def path_occ(s, t):
            """Occupancy sum along the MIN path (D <= 2 fast form)."""
            o1 = port_toward[s, t].astype(jnp.int32)
            m = nbr[s, jnp.maximum(o1, 0)]
            two = self._dist32(s, t) >= 2
            second = jnp.where(two, first_occ(m, t), 0)
            return first_occ(s, t) + second

        len_min = self._dist32(src_r, dst_r)                      # [n_ep]
        len_val = (self._dist32(src_r[:, None], cands)
                   + self._dist32(cands, dst_r[:, None]))
        if mode == "ugal_l":
            occ_min = first_occ(src_r, dst_r)
            occ_val = first_occ(src_r[:, None], cands)
        else:  # ugal_g: smallest sum of queues along the whole path
            occ_min = path_occ(src_r, dst_r)
            occ_val = (path_occ(src_r[:, None], cands)
                       + path_occ(cands, dst_r[:, None]))

        best = ugal_select(len_min, len_val, occ_min, occ_val,
                           ugal_g=(mode == "ugal_g"),
                           unreach=int(UNREACH), big=int(BIG),
                           use_pallas=self.use_pallas)
        inters = jnp.concatenate([dst_r[:, None], cands], axis=1)
        inter = jnp.take_along_axis(inters, best[:, None], 1)[:, 0]
        phase = (best == 0).astype(jnp.int32)                     # MIN: phase 1
        return inter, phase

    # -- allocation ----------------------------------------------------------
    def _desires(self, pkt, router, occ):
        if self.src_route is not None:
            return self._desires_src(pkt)
        dst, inter, phase = pk_dst(pkt), pk_inter(pkt), pk_phase(pkt)
        tgt = jnp.where(phase == 1, dst, inter)
        eject = (dst == router) & (phase == 1)
        min_port = self.port_toward[router, tgt].astype(jnp.int32)
        if self.has_ecmp:
            # dead alternates are skipped automatically: occupancy() is
            # BIG where nbr < 0, so argmin lands on a live port
            opts = self.ecmp_ports[router, tgt].astype(jnp.int32)  # [..., M]
            r_b = jnp.broadcast_to(router[..., None], opts.shape)
            o_occ = jnp.where(opts >= 0,
                              occ[r_b, jnp.maximum(opts, 0)], BIG)
            pick = jnp.argmin(o_occ, axis=-1)
            ecmp_port = jnp.take_along_axis(opts, pick[..., None],
                                            -1)[..., 0]
            if self.mode == "ecmp":
                out_port = ecmp_port
            else:
                # MIN first; equal-cost alternate only when the MIN
                # port is dead (transient failure mask on tables whose
                # routes have not re-converged, DESIGN.md §8)
                min_dead = ((min_port >= 0)
                            & (self.nbr[router,
                                        jnp.maximum(min_port, 0)] < 0))
                out_port = jnp.where(min_dead, ecmp_port, min_port)
            out_port = jnp.where(eject, -1, out_port)
        else:
            out_port = min_port
        out_vc = jnp.minimum(pk_hops(pkt), self.V - 1)
        return out_port, out_vc, eject

    def _desires_src(self, pkt):
        """Source-routed desires: the packet's own path table decides.

        Hop h of message m wants `route_port[gid, h]`; a negative port
        is the eject sentinel at the path's terminal router.  Garbage
        records in zero-initialised queue slots read row 0 harmlessly:
        the allocation kernel masks every request by the cycle-start
        queue depth, so out-of-count slots can never be granted."""
        route_port, vc_base = self.src_route
        M, H = route_port.shape[-2], route_port.shape[-1]
        hops = pk_hops(pkt)
        gid = jnp.clip(self.src_to_gid(pk_msg(pkt)), 0, M - 1)
        out_port = route_port[gid, jnp.minimum(hops, H - 1)]
        out_port = out_port.astype(jnp.int32)
        eject = out_port < 0
        out_vc = jnp.minimum(vc_base[gid].astype(jnp.int32) + hops,
                             self.V - 1)
        return out_port, out_vc, eject

    def alloc(self, nq_pkt, nq_count, sq_pkt, sq_count,
              occ, cycle, eject_fold: Callable, eject_acc,
              tel_state=None, trace_sample=None, trace_extra=None):
        """One cycle of W-round switch allocation + compaction.

        Returns the four queue arrays plus the folded ejection
        accumulator (see the class docstring for the fold contract).
        When `tel_state` is passed (a telemetry.TelemetryState, or `()`
        with telemetry off) it is updated from this cycle's allocation
        outcome and returned as a sixth element; `trace_sample` /
        `trace_extra` carry the engine's flow sampler and injection
        events into the trace ring (repro.sim.telemetry).
        """
        N, P, V, Qn, Qs, W = (self.N, self.P, self.V, self.Qn,
                              self.Qs, self.W)
        PV, PE = P * V, self.p
        n_ep, n_epr = self.n_ep, self.n_epr
        nbr, rev_port = self.nbr, self.rev_port
        ebr = self.ep_block_router

        # ---- the W-slot window is a static slice of the shift-down
        # FIFOs, taken once for all rounds (identities 1 and 2 in the
        # module docstring make this exact).  Slots past the buffer end
        # (W > Qn fig8 configs) are zero-padded; their depth check
        # (count > w) can never pass, matching the seed's wrap rule.
        def head_window(pkt_arr, depth_axis_len):
            wn = min(W, depth_axis_len)
            win = pkt_arr[..., :wn, :]
            if wn < W:
                pad = [(0, 0)] * win.ndim
                pad[-2] = (0, W - wn)
                win = jnp.pad(win, pad)
            return win
        win_net = head_window(nq_pkt, Qn)                      # [N,P,V,W,PK]
        win_src = head_window(sq_pkt, Qs)                      # [n_ep,W,PK]

        r_bcast = jnp.broadcast_to(self.routers_n[..., None], (N, P, V, W))
        ep_bcast = jnp.broadcast_to(self.ep_router[:, None], (n_ep, W))
        n_out, n_vc, n_ej = self._desires(win_net, r_bcast, occ)
        s_out, s_vc, s_ej = self._desires(win_src, ep_bcast, occ)

        def space_of(router, out, vc):
            dr = nbr[router, jnp.maximum(out, 0)]
            dp = rev_port[router, jnp.maximum(out, 0)]
            depth = nq_count[jnp.maximum(dr, 0), jnp.maximum(dp, 0), vc]
            return (out >= 0) & (dr >= 0) & (depth < Qn)
        n_sp = space_of(r_bcast, n_out, n_vc)
        s_sp = space_of(ep_bcast, s_out, s_vc)

        # ---- router-major request arrays for the allocation kernel
        # (W-last layout: the [N,P,V,W] desire arrays reshape in free)
        def rm_net(x):                             # [N,P,V,W] -> [N,PV,W]
            return x.reshape(N, PV, W)

        # routers -> their endpoint block, as a GATHER through the
        # inverse map epr_index (non-endpoint routers gather row 0,
        # masked to zero): bit-identical to the scatter .at[ebr].set
        # it replaces, but XLA CPU serialises scatters per row — and
        # under the sweep engine's lane vmap (sweep.py) a batched
        # scatter is the single hottest lowering in the whole step
        def rm_src(x):                             # [n_ep,W] -> [N,PE,W]
            y = x.reshape(n_epr, PE, W)
            g = y[jnp.maximum(self.epr_index, 0)]
            return jnp.where((self.epr_index >= 0)[:, None, None], g, 0)

        live_q = (nbr >= 0)[:, :, None]
        cnt_net = jnp.where(live_q, nq_count, 0).reshape(N, PV)
        cs_rows = sq_count.reshape(n_epr, PE)[jnp.maximum(self.epr_index, 0)]
        cnt_src = jnp.where((self.epr_index >= 0)[:, None], cs_rows, 0)

        i32 = jnp.int32
        chan_n, ej_n, chan_s, ej_s, win_req = alloc_rounds(
            cycle, rm_net(n_out), rm_net(n_ej.astype(i32)),
            rm_net(n_sp.astype(i32)), cnt_net,
            rm_src(s_out), rm_src(s_ej.astype(i32)),
            rm_src(s_sp.astype(i32)), cnt_src, self.epr_index,
            W=W, P=P, V=V, PE=PE, p_budget=self.p, NQ=self.NQ, R=self.R,
            use_pallas=self.use_pallas)
        cs_net = chan_n.reshape(N, P, V)           # granted window offset
        ej_net = ej_n.reshape(N, P, V)             # (-1 = none), by kind
        cs_src = chan_s[ebr].reshape(n_ep)
        ej_src = ej_s[ebr].reshape(n_ep)

        # ---- engine-specific ejection stats, one fold per round
        for w in range(W):
            eject_acc = eject_fold(eject_acc, ej_net == w, ej_src == w,
                                   win_net[:, :, :, w], win_src[:, w],
                                   cycle)

        # ---- arrivals, as a dense per-(router, port) view: each input
        # port receives at most one packet per cycle, always from its
        # unique upstream channel, so `win_req` of the upstream router
        # identifies the arriving packet with [N, P]-sized gathers — no
        # R-row scatter (XLA CPU scatters serialise per row)
        u_c = jnp.maximum(nbr, 0)                  # upstream router [N,P]
        uo_c = jnp.maximum(rev_port, 0)            # its out port
        wi = win_req[u_c, uo_c]                    # winning request id
        valid = (nbr >= 0) & (wi >= 0)
        is_net = wi < PV
        wi_n = jnp.clip(wi, 0, PV - 1)
        eid = jnp.clip(self.epr_index[u_c] * PE + jnp.maximum(wi - PV, 0),
                       0, n_ep - 1)
        slot = jnp.maximum(
            jnp.where(is_net, chan_n[u_c, wi_n], cs_src[eid]), 0)
        win_net_pm = win_net.reshape(N, PV, W, PK)
        pkt = jnp.where(is_net[..., None],
                        win_net_pm[u_c, wi_n, slot],      # [N,P,PK]
                        win_src[eid, slot])
        vc = jnp.where(is_net,
                       n_vc.reshape(N, PV, W)[u_c, wi_n, slot],
                       s_vc[eid, slot])
        here = jnp.arange(N)[:, None]
        w2 = bump_hops_word(pkt[..., 2],
                            (here == pk_inter(pkt)).astype(jnp.int32))
        pkt = jnp.concatenate([pkt[..., :2], w2[..., None]], axis=-1)
        arrived = valid[..., None] & (jnp.arange(V) == vc[..., None])

        # ---- telemetry (data-only: nothing below reads tel_state).
        # Placed before the dequeue so the counters see the same
        # cycle-start queue depths the kernel saw.
        if tel_state is not None and self.tel.enabled:
            cs_t, tr_t = tel_state
            if self.tel.counters:
                cs_t = tel.counters.count_cycle(cs_t, nq_count)
                cs_t = tel.counters.count_alloc(
                    cs_t, self, cycle, win_net, win_src, win_req,
                    cs_net, ej_net, cs_src, ej_src, cnt_net, sq_count)
            if self.tel.trace:
                tr_t = tel.trace.trace_alloc(
                    tr_t, self, cycle, valid, pkt, win_net, win_src,
                    ej_net, ej_src, trace_sample, trace_extra)
            tel_state = tel.TelemetryState(cs_t, tr_t)

        # ---- dequeue + compaction: removing the granted packet at
        # offset g is a static-shift select (slots >= g take their
        # successor) — order-preserving, no gathers or scatters; then
        # the arrival is inserted at the post-dequeue tail by a masked
        # select (one arrival per (router, port) per cycle)
        g_net = jnp.maximum(cs_net, ej_net)
        g_src = jnp.maximum(cs_src, ej_src)
        deq_net = (g_net >= 0).astype(jnp.int32)
        deq_src = (g_src >= 0).astype(jnp.int32)

        sidx = jnp.arange(Qn, dtype=jnp.int32)
        up_net = jnp.concatenate(
            [nq_pkt[:, :, :, 1:], jnp.zeros_like(nq_pkt[:, :, :, :1])],
            axis=3)
        drop_m = (g_net[..., None] >= 0) & (sidx >= g_net[..., None])
        nq_pkt = jnp.where(drop_m[..., None], up_net, nq_pkt)
        tail = (nq_count - deq_net)[..., None]             # [N,P,V,1]
        ins = arrived[..., None] & (sidx == tail)          # [N,P,V,Qn]
        nq_pkt = jnp.where(ins[..., None], pkt[:, :, None, None, :],
                           nq_pkt)

        s_sidx = jnp.arange(Qs, dtype=jnp.int32)
        up_src = jnp.concatenate(
            [sq_pkt[:, 1:], jnp.zeros_like(sq_pkt[:, :1])], axis=1)
        s_drop = (g_src[:, None] >= 0) & (s_sidx >= g_src[:, None])
        sq_pkt = jnp.where(s_drop[..., None], up_src, sq_pkt)

        nq_count = nq_count + arrived.astype(jnp.int32) - deq_net
        sq_count = sq_count - deq_src

        if tel_state is None:
            return (nq_pkt, nq_count, sq_pkt, sq_count, eject_acc)
        return (nq_pkt, nq_count, sq_pkt, sq_count, eject_acc, tel_state)


def _open_loop_fold(acc, g_net, g_src, pkt_net, pkt_src, cycle):
    """Open-loop ejection stats: delivered count + latency sum."""
    delivered, lat_sum = acc
    delivered = (delivered + g_net.sum().astype(jnp.int32)
                 + g_src.sum().astype(jnp.int32))
    lat = (jnp.where(g_net, cycle - pk_time(pkt_net) + 1, 0).sum()
           + jnp.where(g_src, cycle - pk_time(pkt_src) + 1, 0).sum())
    return delivered, lat_sum + lat.astype(jnp.float32)


# (tables, traffic, static-config) -> compiled (carry, rate) -> per-cycle
# stats.  The single-lane runner keeps the routing tables as CLOSURE
# CONSTANTS: XLA specialises the per-cycle gathers against constant
# index tables (measured ~2.5x at q=11 vs operand tables), so the
# single-lane hot path deliberately recompiles per failure mask — a
# sweep over masks belongs on the lane-batched path (repro.sim.sweep),
# which lifts the tables into traced operands and pays one compile for
# all masks (DESIGN.md §10).  Values pin the tables/traffic objects so
# the id() keys cannot be silently reused by the allocator; the FIFO
# bound keeps a long-lived process from accumulating compiled
# executables without limit.
_OPEN_LOOP_CACHE: dict = {}
_CACHE_MAX = 32


def _cache_put(cache: dict, key, value) -> None:
    while len(cache) >= _CACHE_MAX:
        cache.pop(next(iter(cache)))
    cache[key] = value


def tables_signature(tables: SimTables) -> tuple:
    """Compile-relevant structure of a table set: everything that shapes
    the traced step EXCEPT the mask-dependent array values."""
    return (tables.n_routers, tables.P, tables.p, tables.n_endpoints,
            None if tables.ecmp_ports is None
            else tables.ecmp_ports.shape[-1],
            tables.ep_router.tobytes())


def _open_loop_step(core: SwitchCore, traffic: Traffic, rate):
    """One-cycle step closure of the open-loop engine for `core`.

    Rank-polymorphic by construction: the sweep engine maps this exact
    function over a lane axis with jax.vmap, so per-lane results are
    bit-identical to L sequential runs (tests/test_sweep.py)."""
    active = jnp.asarray(traffic.active)
    n_ep, Qs = core.n_ep, core.Qs
    sample = traffic.sample
    tcfg = core.tel
    sampler = (tel.trace.flow_sampler(tcfg.trace_sample_shift)
               if tcfg.trace else None)

    def step(carry, cycle):
        nq_pkt, nq_count, sq_pkt, sq_count, key, ts = carry
        key, k_inj, k_dst, k_rt = jax.random.split(key, 4)

        occ = core.occupancy(nq_count)

        # ---- injection ----------------------------------------------------
        coin = jax.random.bernoulli(k_inj, rate, (n_ep,)) & active
        want = coin & (sq_count < Qs)
        dropped = (coin & (sq_count >= Qs)).sum()
        dst_ep = sample(k_dst)
        dst_r = core.ep_router[dst_ep]
        inter, phase = core.route_decision(dst_r, occ, k_rt)
        new_pkt = pack_record(dst_r, inter, cycle,
                              jnp.zeros((n_ep,), jnp.int32), phase)
        sq_pkt, sq_count = core.inject(sq_pkt, sq_count, want, new_pkt)
        injected = want.sum()

        # ---- telemetry at the injection point (data-only)
        extra = None
        if tcfg.counters:
            ts = tel.TelemetryState(
                tel.counters.count_routes(ts.counters, want, phase),
                ts.trace)
        if tcfg.trace:
            extra = (want & sampler(new_pkt),
                     tel.trace.pack_events(cycle, tel.trace.KIND_INJECT,
                                           core.ep_router,
                                           tel.trace.PORT_EP, new_pkt))

        # ---- shared switch pipeline ---------------------------------------
        (nq_pkt, nq_count, sq_pkt, sq_count,
         (delivered, lat_sum), ts) = core.alloc(
             nq_pkt, nq_count, sq_pkt, sq_count,
             occ, cycle, _open_loop_fold,
             (jnp.int32(0), jnp.float32(0.0)),
             tel_state=ts, trace_sample=sampler, trace_extra=extra)

        in_flight = (nq_count.sum() + sq_count.sum()).astype(jnp.int32)
        stats = (injected.astype(jnp.int32), delivered,
                 lat_sum, sq_count.sum().astype(jnp.int32),
                 dropped.astype(jnp.int32), in_flight)
        return (nq_pkt, nq_count, sq_pkt, sq_count, key, ts), stats

    return step


def _open_loop_runner(tables: SimTables, traffic: Traffic, cfg: SimConfig):
    """Compiled (carry0, rate) -> (final carry, per-cycle stats), with
    the initial carry DONATED (its buffers are reused for the scan
    state, DESIGN.md §10) and the tables baked in as constants."""
    key = (id(tables), id(traffic), cfg.static_key())
    hit = _OPEN_LOOP_CACHE.get(key)
    if hit is not None and hit[0] is tables and hit[1] is traffic:
        return hit[2]

    core = SwitchCore(tables, cfg)

    def run(carry, rate):
        step = _open_loop_step(core, traffic, rate)
        cycles = jnp.arange(cfg.cycles, dtype=jnp.int32)
        carry, stats = jax.lax.scan(step, carry, cycles)
        # the final carry is returned (and dropped by callers) so the
        # DONATED initial carry has aliasable targets: the queue-state
        # buffers are reused in place instead of being double-allocated
        # (peak-memory assertion in tests/test_engine_scaling.py)
        return carry, stats

    fn = jax.jit(run, donate_argnums=(0,))
    _cache_put(_OPEN_LOOP_CACHE, key, (tables, traffic, (core, fn)))
    return core, fn


def _assemble_result(tables: SimTables, traffic: Traffic, cfg: SimConfig,
                     n_active: int, stats: tuple,
                     telemetry: Optional[TelemetrySnapshot] = None
                     ) -> SimResult:
    """Host-side reduction of per-cycle scan stats into a SimResult
    (shared by `simulate` and the lane-batched sweep engine)."""
    inj, dlv, lat, occ_s, drop, infl = stats
    inj = np.asarray(inj, dtype=np.int64)
    dlv = np.asarray(dlv, dtype=np.int64)
    lat = np.asarray(lat, dtype=np.float64)
    occ_s = np.asarray(occ_s, dtype=np.float64)
    drop = np.asarray(drop, dtype=np.int64)
    infl = np.asarray(infl, dtype=np.int64)

    n_ep = tables.n_endpoints
    w = cfg.warmup
    meas = slice(w, cfg.cycles)
    m_cycles = cfg.cycles - w
    delivered_m = int(dlv[meas].sum())
    accepted = delivered_m / (m_cycles * max(n_active, 1))
    avg_lat = float(lat[meas].sum() / max(delivered_m, 1))
    return SimResult(
        name=f"{traffic.name}-{cfg.mode}",
        offered_load=cfg.injection_rate,
        accepted_load=float(accepted),
        avg_latency=avg_lat,
        delivered=int(dlv.sum()),
        injected=int(inj.sum()),
        dropped_at_source=int(drop.sum()),
        src_occupancy=float(occ_s[meas].mean() / max(n_ep, 1)),
        per_cycle_delivered=dlv,
        per_cycle_injected=inj,
        per_cycle_in_flight=infl,
        per_cycle_dropped=drop,
        q_src=cfg.q_src,
        telemetry=telemetry,
    )


def simulate(tables: SimTables, traffic: Traffic, cfg: SimConfig) -> SimResult:
    n_active = int(traffic.active.sum())
    core, fn = _open_loop_runner(tables, traffic, cfg)
    carry0 = (core.init_queues() + (jax.random.PRNGKey(cfg.seed),
                                    tel.init_state(cfg.telemetry, core)))
    carry, stats = fn(carry0, jnp.float32(cfg.injection_rate))
    snap = tel.snapshot(cfg.telemetry, carry[5], cfg.cycles)
    return _assemble_result(tables, traffic, cfg, n_active, stats, snap)
