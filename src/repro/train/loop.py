"""Training loop: jitted step (grad-accum microbatching, remat policy),
checkpoint/restart, straggler + preemption hooks.

`make_train_step` builds a pjit-able step working on GLOBAL arrays; the
same function serves the CPU smoke tests (1 device) and the 512-chip
dry-run (it is what launch/dryrun.py lowers).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.model import loss_fn
from ..optim.adamw import AdamWConfig, adamw_update, init_opt_state
from ..launch.faults import FaultMonitor

__all__ = ["TrainConfig", "make_train_step", "train"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1            # gradient accumulation
    remat: str = "none"              # none | full | dots_saveable
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    log_every: int = 10


def _remat_policy(name: str):
    if name == "full":
        return None                          # save nothing, recompute all
    if name == "dots_saveable":
        return jax.checkpoint_policies.dots_saveable
    raise ValueError(name)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    tc: TrainConfig) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    base_loss = loss_fn
    if tc.remat != "none":
        base_loss = jax.checkpoint(
            loss_fn, policy=_remat_policy(tc.remat),
            static_argnums=(2,))

    def step(params, opt_state, batch):
        if tc.microbatches > 1:
            def micro(i, acc):
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // tc.microbatches),
                        x.shape[0] // tc.microbatches, 0), batch)
                l, g = jax.value_and_grad(base_loss)(params, mb, cfg)
                return (acc[0] + l,
                        jax.tree.map(jnp.add, acc[1], g))

            zero = (jnp.zeros(()),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            loss_sum, grads = jax.lax.fori_loop(0, tc.microbatches, micro,
                                                zero)
            loss = loss_sum / tc.microbatches
            grads = jax.tree.map(lambda g: g / tc.microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(base_loss)(params, batch, cfg)

        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             opt_cfg)
        return params, opt_state, dict(loss=loss, **om)

    return step


def train(cfg: ModelConfig, opt_cfg: AdamWConfig, tc: TrainConfig,
          data_source, params, n_steps: int,
          monitor: Optional[FaultMonitor] = None,
          jit: bool = True):
    """Run n_steps; resumes from tc.ckpt_dir if a checkpoint exists.
    Returns (params, opt_state, history)."""
    from ..ckpt.checkpoint import (latest_step, restore_checkpoint,
                                   save_checkpoint)

    opt_state = init_opt_state(params, opt_cfg)
    start = 0
    if tc.ckpt_dir:
        last = latest_step(tc.ckpt_dir)
        if last is not None:
            tree = restore_checkpoint(tc.ckpt_dir, last,
                                      dict(p=params, o=opt_state))
            params, opt_state = tree["p"], tree["o"]
            start = last

    step_fn = make_train_step(cfg, opt_cfg, tc)
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        # donation consumes the caller's buffers — keep the caller's params
        # usable by working on a private copy
        params = jax.tree.map(jnp.copy, params)

    history = []
    pending_save = None
    for step in range(start, n_steps):
        t0 = time.time()
        batch = data_source.batch_at(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if monitor is not None:
            monitor.heartbeat(step)
            if monitor.should_checkpoint_and_exit():
                save_checkpoint(tc.ckpt_dir, step + 1,
                                dict(p=params, o=opt_state))
                return params, opt_state, history
        if step % tc.log_every == 0:
            loss = float(metrics["loss"])
            history.append(dict(step=step, loss=loss,
                                dt=time.time() - t0))
        if tc.ckpt_dir and (step + 1) % tc.ckpt_every == 0:
            if pending_save is not None:
                pending_save.join()
            pending_save = save_checkpoint(
                tc.ckpt_dir, step + 1, dict(p=params, o=opt_state),
                async_save=True)
    if pending_save is not None:
        pending_save.join()
    return params, opt_state, history
