"""Batched serving engine (continuous-batching-lite).

Fixed B decode slots; finished sequences are refilled from the request
queue; prefill runs per-request (padded to the slot shape) and splices
its KV into the batch cache.  Demo-grade but end-to-end: examples/serve.py
drives it and tests/test_serving.py checks slot bookkeeping + output
consistency with the single-sequence path.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.model import decode_step, init_cache, prefill

__all__ = ["Request", "ServingEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # [S] int32
    max_new_tokens: int
    out_tokens: Optional[List[int]] = None


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, batch_slots: int = 4,
                 max_len: int = 512, dtype=jnp.float32,
                 sampler: Optional[Callable] = None):
        if cfg.n_encoder_layers:
            raise NotImplementedError(
                "ServingEngine handles decoder-only archs; use "
                "prefill/decode_step directly for enc-dec (whisper)")
        self.params, self.cfg = params, cfg
        self.B, self.max_len = batch_slots, max_len
        self.cache = init_cache(cfg, batch_slots, max_len, dtype)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_remaining = np.zeros(batch_slots, np.int64)
        self.cur_tokens = jnp.zeros((batch_slots, 1), jnp.int32)
        self.sampler = sampler or (lambda logits: jnp.argmax(logits, -1))
        self._decode = jax.jit(
            lambda p, t, c: decode_step(p, t, self.cfg, c))

    # -- admission ---------------------------------------------------------
    def _admit(self, slot: int, req: Request):
        """Prefill a single request and splice its cache into `slot`."""
        cfg = self.cfg
        batch = dict(tokens=jnp.asarray(req.prompt[None], jnp.int32))
        one_cache = init_cache(cfg, 1, self.max_len, jnp.float32)
        logits, one_cache = prefill(self.params, batch, cfg, one_cache)

        def splice(dst, src):
            if dst.ndim == 0 or dst.shape[0] != self.B:
                return dst
            return dst.at[slot].set(src[0].astype(dst.dtype))

        self.cache = jax.tree.map(splice, self.cache, one_cache)
        first = self.sampler(logits[:, -1])
        self.cur_tokens = self.cur_tokens.at[slot, 0].set(first[0])
        req.out_tokens = [int(first[0])]
        self.slot_req[slot] = req
        self.slot_remaining[slot] = req.max_new_tokens - 1

    # -- main loop ----------------------------------------------------------
    def run(self, requests: List[Request], max_steps: int = 10_000):
        queue = list(requests)
        done: List[Request] = []
        steps = 0
        while (queue or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            # fill empty slots
            for s in range(self.B):
                if self.slot_req[s] is None and queue:
                    self._admit(s, queue.pop(0))
            # one decode step for the whole batch
            logits, self.cache = self._decode(self.params, self.cur_tokens,
                                              self.cache)
            nxt = self.sampler(logits[:, -1])
            self.cur_tokens = nxt[:, None].astype(jnp.int32)
            steps += 1
            for s in range(self.B):
                req = self.slot_req[s]
                if req is None:
                    continue
                req.out_tokens.append(int(nxt[s]))
                self.slot_remaining[s] -= 1
                if self.slot_remaining[s] <= 0:
                    done.append(req)
                    self.slot_req[s] = None
        done.extend(r for r in self.slot_req if r is not None)
        return done
