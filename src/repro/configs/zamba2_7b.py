"""zamba2-7b [hybrid]: 81L d_model=3584, Mamba2 backbone (d_state=64)
with a SHARED attention+MLP block applied every 6 layers (32H, kv=32 MHA,
d_ff=14336).  [arXiv:2411.15242; unverified]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32_000,
    d_state=64,
    n_ssm_heads=8,
    ssm_head_dim=896,        # d_inner = 2 * d_model = 7168
    attn_every=6,            # shared attention block cadence
    supports_long=True,      # SSM backbone: linear-state long context
)
