"""Architecture config schema + the shape suite assigned to this paper."""

from __future__ import annotations

import dataclasses
from typing import List, Optional

__all__ = ["ModelConfig", "SHAPES", "ShapeSpec"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None              # default d_model // n_heads
    rope_theta: float = 10_000.0
    # attention pattern
    sliding_window: Optional[int] = None
    global_every: int = 0           # >0: layer i is global iff (i+1) % ge == 0,
                                    # others use sliding_window (gemma pattern)
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    qk_norm: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1              # layer i is MoE iff (i % moe_every) == moe_every-1
    shared_expert: bool = False
    capacity_factor: float = 1.25   # expert capacity = T*k/E * cf (Switch)
    # SSM / hybrid
    d_state: int = 0
    n_ssm_heads: int = 0
    ssm_head_dim: int = 0
    attn_every: int = 0             # zamba: shared attn block every k layers
    # xLSTM
    slstm_every: int = 0            # block i is sLSTM iff (i+1) % se == 0
    # encoder-decoder / frontends
    n_encoder_layers: int = 0
    n_frontend_tokens: int = 0
    frontend: str = "none"          # none | audio_stub | vision_stub
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # which long-context shape classes this arch supports (DESIGN.md §4)
    supports_long: bool = False
    # compile-time/scale feature: lax.scan over the repeating layer unit
    # (MaxText-style).  Ignored for enc-dec (whisper).  The layer pattern
    # period is derived automatically (gemma3: 6, gemma2/llama4: 2,
    # xlstm: 8, zamba2: 6, dense: 1).
    scan_layers: bool = False
    # sequence-chunked cross-entropy / unembed (never materialises the
    # [B, S, vocab] logits in f32)
    loss_chunk: int = 1024
    # activation-sharding hints (set by the launcher; empty = no
    # constraints, e.g. single-device smoke tests).  dp_axes: mesh axes
    # carrying the batch; tp_axis: the tensor-parallel axis (vocab/heads).
    dp_axes: tuple = ()
    tp_axis: Optional[str] = None
    # shard the attention core over the SEQUENCE dim of the tp axis
    # (context parallelism) — the right layout when n_kv_heads < tp size
    # (padding heads wastes chips and emits giant score all-reduces)
    attn_seq_shard: bool = False
    # MoE layout: True -> expert-parallel (n_experts divides tp size);
    # False -> group-local dispatch (G = dp size groups, expert d_ff
    # sharded over tp); None -> no constraints (smoke tests)
    moe_ep: Optional[bool] = None
    moe_groups: int = 1

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def layer_kinds(self) -> List[dict]:
        """Per-decoder-layer spec: kind, ffn, window."""
        out = []
        for i in range(self.n_layers):
            if self.family == "ssm":
                kind = ("slstm" if self.slstm_every
                        and (i + 1) % self.slstm_every == 0 else "mlstm")
                out.append(dict(kind=kind, ffn=None, window=None))
                continue
            if self.family == "hybrid":
                shared = self.attn_every and (i + 1) % self.attn_every == 0
                out.append(dict(kind="mamba", ffn=None, window=None,
                                shared_attn=bool(shared)))
                continue
            # attention families
            window = None
            if self.sliding_window:
                is_global = (self.global_every
                             and (i + 1) % self.global_every == 0)
                window = None if is_global else self.sliding_window
                if not self.global_every:
                    window = self.sliding_window      # all-SWA (mistral style)
            ffn = "dense"
            if self.n_experts and (i % self.moe_every) == self.moe_every - 1:
                ffn = "moe"
            out.append(dict(kind="attn", ffn=ffn, window=window))
        return out

    def pattern_period(self) -> int:
        """Smallest P with layer_kinds()[i] == layer_kinds()[i-P]."""
        specs = self.layer_kinds()
        for P in range(1, len(specs) + 1):
            if all(specs[i] == specs[i - P] for i in range(P, len(specs))):
                return P
        return len(specs)

    def scan_split(self):
        """(period, n_units, n_tail) for scan-over-layers."""
        P = self.pattern_period()
        n_units = self.n_layers // P
        return P, n_units, self.n_layers - n_units * P

    def attn_layer_cfg(self, window=None, causal=True) -> dict:
        return dict(n_heads=self.n_heads, n_kv_heads=self.n_kv_heads,
                    head_dim=self.hd, window=window, cap=self.attn_softcap,
                    rope_theta=self.rope_theta, causal=causal,
                    dp_axes=self.dp_axes, tp_axis=self.tp_axis,
                    seq_shard=self.attn_seq_shard)

    def ssm_layer_cfg(self) -> dict:
        return dict(n_ssm_heads=self.n_ssm_heads,
                    ssm_head_dim=self.ssm_head_dim, d_state=self.d_state)

    def xlstm_layer_cfg(self) -> dict:
        return dict(n_heads=self.n_heads, head_dim=self.hd)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}
