"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1, interleaved (every other
layer) + shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202_048,
    rope_theta=500_000.0,
    n_experts=128,
    top_k=1,
    moe_every=2,             # interleaved MoE
    shared_expert=True,
    tie_embeddings=False,
    supports_long=False,
)
