"""xlstm-1.3b [ssm]: 48 blocks d_model=2048 4H — mLSTM blocks with an
sLSTM block every 8th position (the 7:1 xLSTM mix).  d_ff=0: the blocks
carry their own projections.  [arXiv:2405.04517; unverified]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab=50_304,
    slstm_every=8,
    tie_embeddings=False,
    supports_long=True,
)
