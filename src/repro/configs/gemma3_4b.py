"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 — 5:1 local:global interleave, 128k context, qk-norm.
[hf:google/gemma-3-1b-pt; unverified]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab=262_144,
    rope_theta=1_000_000.0,
    sliding_window=1024,
    global_every=6,          # 5 local : 1 global
    qk_norm=True,
    supports_long=True,      # windowed local layers carry 500k decode
)
