"""Architecture registry: one module per assigned arch, `get(name)` +
`reduced(cfg)` for CPU smoke tests.  Select with --arch <id>."""

from __future__ import annotations

import dataclasses

from .base import SHAPES, ModelConfig, ShapeSpec
from .gemma3_4b import CONFIG as gemma3_4b
from .h2o_danube_1_8b import CONFIG as h2o_danube_1_8b
from .gemma2_2b import CONFIG as gemma2_2b
from .yi_34b import CONFIG as yi_34b
from .llama4_maverick_400b_a17b import CONFIG as llama4_maverick
from .mixtral_8x22b import CONFIG as mixtral_8x22b
from .zamba2_7b import CONFIG as zamba2_7b
from .xlstm_1_3b import CONFIG as xlstm_1_3b
from .phi_3_vision_4_2b import CONFIG as phi_3_vision
from .whisper_small import CONFIG as whisper_small

ARCHS = {
    "gemma3-4b": gemma3_4b,
    "h2o-danube-1.8b": h2o_danube_1_8b,
    "gemma2-2b": gemma2_2b,
    "yi-34b": yi_34b,
    "llama4-maverick-400b-a17b": llama4_maverick,
    "mixtral-8x22b": mixtral_8x22b,
    "zamba2-7b": zamba2_7b,
    "xlstm-1.3b": xlstm_1_3b,
    "phi-3-vision-4.2b": phi_3_vision,
    "whisper-small": whisper_small,
}

__all__ = ["ARCHS", "SHAPES", "ModelConfig", "ShapeSpec", "get", "reduced"]


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(cfg: ModelConfig, n_layers: int = 4) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests: small widths, few
    experts, tiny vocab, short pattern periods — one train/forward step
    must run in seconds."""
    kv = max(1, min(cfg.n_kv_heads, 2))
    heads = max(kv, min(cfg.n_heads, 4))
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, n_layers),
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window
        else None,
        global_every=2 if cfg.global_every else 0,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        moe_every=min(cfg.moe_every, 2),
        d_state=16 if cfg.d_state else 0,
        n_ssm_heads=2 if cfg.n_ssm_heads else 0,
        ssm_head_dim=32 if cfg.ssm_head_dim else 0,
        attn_every=2 if cfg.attn_every else 0,
        slstm_every=2 if cfg.slstm_every else 0,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        n_frontend_tokens=8 if cfg.n_frontend_tokens else 0,
    )
