"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000 — local/global alternating, logit softcaps.
[arXiv:2408.00118; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256_000,
    sliding_window=4096,
    global_every=2,          # alternating local / global
    attn_softcap=50.0,
    final_softcap=30.0,
    supports_long=True,
)
