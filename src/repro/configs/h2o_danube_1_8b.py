"""h2o-danube-1.8b [dense]: 24L d_model=2560 32H (GQA kv=8)
d_ff=6912 vocab=32000 — llama+mistral mix, sliding-window attention.
[arXiv:2401.16818; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab=32_000,
    sliding_window=4096,     # all-SWA (mistral style)
    global_every=0,
    supports_long=True,
)
