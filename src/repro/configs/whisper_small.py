"""whisper-small [audio]: enc-dec, 12+12L d_model=768 12H d_ff=3072
vocab=51865 — conv frontend is a STUB (input_specs provides precomputed
frame embeddings, 1500 frames).  [arXiv:2212.04356; unverified]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51_865,
    n_encoder_layers=12,
    frontend="audio_stub",
    n_frontend_tokens=1500,
    supports_long=False,
)
