"""Persistent performance-regression harness (DESIGN.md §9).

`harness` provides steady-state timing (explicit warmup/compile
separation), peak-memory probes, and a stable JSON schema
(``BENCH_*.json``) so benchmark trajectories survive across PRs and a
CI gate can fail on hot-path regressions.
"""

from .harness import (BenchEntry, bench_callable, check_regression,
                      enable_compilation_cache, load_bench,
                      lowering_breakdown, peak_memory_bytes, repo_stamp,
                      rss_hwm_bytes, write_bench)

__all__ = ["BenchEntry", "bench_callable", "check_regression",
           "enable_compilation_cache", "load_bench", "lowering_breakdown",
           "peak_memory_bytes", "repo_stamp", "rss_hwm_bytes",
           "write_bench"]
