"""Timing, memory, and JSON persistence for ``BENCH_*.json`` files.

Methodology:

- `bench_callable` separates the first call (trace + compile + device
  warmup, with the memory probe bracketing it) from the steady-state
  measurement: it times `repeats` further calls and reports min/mean
  wall seconds.  The min is the regression-gate number — it is the
  least noisy estimator on shared CI machines; the compile time is
  reported separately because a tracing regression is a real
  regression too.
- `peak_memory_bytes` prefers the JAX device allocator's
  ``peak_bytes_in_use`` (TPU/GPU); on CPU hosts, where the allocator
  exposes no stats, it falls back to `tracemalloc` around one call.
  tracemalloc only sees host-side Python allocations (device buffers
  are invisible to it), so that number is a coarse host-traffic proxy
  — which probe produced an entry is recorded in its ``mem_probe``
  field so trajectories never silently mix the two.  Paper-scale
  entries use the near-free RSS high-water probe (``cheap=True`` /
  ``measure_memory="rss"``) instead of tracemalloc, whose hooks would
  dominate a q=17 run; ``peak_mem_bytes`` is therefore never null.
- `enable_compilation_cache` points JAX's persistent compilation cache
  at ``$REPRO_CACHE_DIR`` (no-op when unset) and reports whether the
  directory was cold or warm, so benchmark wall times can distinguish
  a real XLA compile from a cache deserialize.  CI persists the
  directory across runs.

Schema (``BENCH_*.json``)::

    {"schema": 1, "suite": "engine_scaling", "backend": "cpu",
     "entries": {"<name>": {"wall_s": .., "compile_s": ..,
                            "cycles": .., "cycles_per_sec": ..,
                            "peak_mem_bytes": .., "mem_probe": "..",
                            "meta": {...}}}}

`check_regression` compares one metric of one entry between a baseline
file and fresh numbers with a multiplicative tolerance, for the CI
gate (``benchmarks/engine_scaling.py --check-regression``).  Machine
speeds differ between the laptop that wrote the baseline and the CI
runner, so gate factors must stay coarse (the default CI gate is 2x).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
import tracemalloc
from typing import Callable, Optional

__all__ = ["BenchEntry", "bench_callable", "peak_memory_bytes",
           "rss_hwm_bytes", "enable_compilation_cache",
           "write_bench", "load_bench", "check_regression",
           "repo_stamp", "lowering_breakdown"]

SCHEMA_VERSION = 1

_GIT_SHA_CACHE: list = []


def repo_stamp(telemetry: bool = False) -> dict:
    """Provenance stamp for a BENCH entry's meta: the git SHA of the
    working tree, the jax version, and whether the benched path had
    telemetry enabled — so BENCH_*.json trajectories stay attributable
    across PRs and across telemetry-on/off configurations."""
    import jax

    if not _GIT_SHA_CACHE:
        sha = "unknown"
        try:
            import subprocess
            out = subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"],
                cwd=os.path.dirname(os.path.abspath(__file__)),
                capture_output=True, text=True, timeout=10)
            if out.returncode == 0:
                sha = out.stdout.strip()
        except Exception:
            pass
        _GIT_SHA_CACHE.append(sha)
    return {"git_sha": _GIT_SHA_CACHE[0], "jax_version": jax.__version__,
            "telemetry": bool(telemetry)}


def lowering_breakdown(fn, *args) -> dict:
    """Split a jitted callable's pre-execution cost into tracing/
    lowering vs XLA compilation, in seconds: ``{"trace_lower_s": ..,
    "xla_compile_s": ..}``.  Telemetry changes the traced graph (extra
    carry arrays, counter updates), so benchmarks report both phases
    separately to show where a config's compile tax actually goes.
    `fn` must be a jax.jit-wrapped callable (it needs `.lower`)."""
    t0 = time.perf_counter()
    lowered = fn.lower(*args)
    t1 = time.perf_counter()
    lowered.compile()
    t2 = time.perf_counter()
    return {"trace_lower_s": t1 - t0, "xla_compile_s": t2 - t1}


def enable_compilation_cache() -> tuple:
    """Point JAX's persistent compilation cache at ``$REPRO_CACHE_DIR``.

    Returns ``(state, cache_dir)`` where state is:
      - ``"off"``   — env var unset, nothing configured;
      - ``"cold"``  — cache enabled, directory empty (compiles will
        populate it);
      - ``"warm"``  — cache enabled and already populated (compiles
        with unchanged HLO deserialize instead of re-running XLA).

    Call this BEFORE the first jit of the process (benchmarks.run /
    engine_scaling do it at main() entry).  The min-compile-time gate
    is lowered to 1s so the big simulator scans always persist, and
    entries are written on every backend including CPU.  The sweep
    engine's tables-as-operands design is what makes the cache useful
    for fault studies at all: masks live in operands, not in the HLO,
    so every failure sample of a topology hits one cache entry
    (DESIGN.md §10).
    """
    cache_dir = os.environ.get("REPRO_CACHE_DIR", "")
    if not cache_dir:
        return "off", None
    import jax

    os.makedirs(cache_dir, exist_ok=True)
    state = "warm" if any(
        name.endswith("-cache") for name in os.listdir(cache_dir)) else "cold"
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 1.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    return state, cache_dir


@dataclasses.dataclass
class BenchEntry:
    name: str
    wall_s: float                       # steady-state min wall seconds/call
    wall_mean_s: float                  # steady-state mean
    compile_s: float                    # first call (trace+compile+run)
    repeats: int
    cycles: Optional[int] = None        # simulated cycles per call
    peak_mem_bytes: Optional[int] = None
    # device | tracemalloc | tracemalloc-nested | rss | rss-total |
    # none (rss-total = absolute VmHWM when an earlier, larger workload
    # hides this call behind the monotone high-water mark)
    mem_probe: str = "none"
    meta: dict = dataclasses.field(default_factory=dict)
    # additional top-level gate metrics (e.g. sweep_points_per_sec) —
    # serialized beside cycles_per_sec so check_regression can address
    # them by name
    extra_metrics: dict = dataclasses.field(default_factory=dict)

    @property
    def cycles_per_sec(self) -> Optional[float]:
        if self.cycles is None or self.wall_s <= 0:
            return None
        return self.cycles / self.wall_s

    def to_json(self) -> dict:
        d = {
            "wall_s": self.wall_s,
            "wall_mean_s": self.wall_mean_s,
            "compile_s": self.compile_s,
            "repeats": self.repeats,
            "peak_mem_bytes": self.peak_mem_bytes,
            "mem_probe": self.mem_probe,
            "meta": self.meta,
        }
        if self.cycles is not None:
            d["cycles"] = self.cycles
            d["cycles_per_sec"] = self.cycles_per_sec
        d.update(self.extra_metrics)
        return d


def rss_hwm_bytes() -> Optional[int]:
    """Process peak resident-set size (VmHWM) in bytes, or None when
    the platform exposes neither /proc nor getrusage."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM:"):
                    return int(line.split()[1]) * 1024
    except OSError:
        pass
    try:
        import resource
        import sys
        ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        # ru_maxrss is bytes on macOS, KiB everywhere else
        return int(ru) * (1 if sys.platform == "darwin" else 1024)
    except Exception:
        return None


def peak_memory_bytes(fn: Callable[[], object],
                      cheap: bool = False) -> tuple:
    """(peak_bytes, probe_kind) for one invocation of `fn`.

    Uses the device allocator's peak counter when the backend exposes
    one (delta vs the pre-call peak), else tracemalloc.  With
    ``cheap=True`` (or as the last-resort fallback) the probe reads the
    process RSS high-water mark instead: near-zero overhead — the
    tracemalloc hooks dominate paper-scale runs — at the cost of
    coarser attribution.  A call that does not move the monotone HWM
    reports the absolute mark with probe ``"rss-total"`` so
    ``peak_mem_bytes`` is never null.
    """
    import jax

    if cheap:
        before = rss_hwm_bytes()
        fn()
        after = rss_hwm_bytes()
        if after is None:
            return None, "none"
        if before is not None and after > before:
            return int(after - before), "rss"
        # an earlier larger workload hides this call behind the HWM:
        # report the absolute mark, clearly labelled
        return int(after), "rss-total"

    dev = jax.devices()[0]
    stats = getattr(dev, "memory_stats", lambda: None)()
    if stats and "peak_bytes_in_use" in stats:
        before = dev.memory_stats()["peak_bytes_in_use"]
        fn()
        after = dev.memory_stats()["peak_bytes_in_use"]
        if after > before:
            return int(after - before), "device"
        # the allocator peak is a monotone high-water mark: an earlier,
        # larger workload in this process hides this call entirely —
        # fall back to the absolute RSS mark rather than reporting
        # nothing (mem_probe records which probe produced the number)
        rss = rss_hwm_bytes()
        return (int(rss), "rss-total") if rss is not None else (None, "none")
    if tracemalloc.is_tracing():
        # don't clobber an enclosing session's peak with reset_peak();
        # approximate from the running counters and label the probe so
        # trajectories never silently mix it with clean readings (a
        # stale historical peak can dominate peak1 here)
        cur0, _ = tracemalloc.get_traced_memory()
        fn()
        _, peak1 = tracemalloc.get_traced_memory()
        return int(max(peak1 - cur0, 0)), "tracemalloc-nested"
    tracemalloc.start()
    try:
        fn()
        _, peak = tracemalloc.get_traced_memory()
    finally:
        tracemalloc.stop()
    return int(peak), "tracemalloc"


def bench_callable(name: str, fn: Callable[[], object], *,
                   repeats: int = 3, cycles: Optional[int] = None,
                   measure_memory=True,
                   meta: Optional[dict] = None,
                   telemetry: bool = False) -> BenchEntry:
    """Compile-vs-steady-state timing of `fn` (which must block until
    the result is materialised — call block_until_ready/np.asarray
    inside).

    The memory probe brackets the FIRST call: on allocator-stats
    backends the peak counter is a monotone high-water mark, so only
    the first execution moves it — probing a later call would read a
    zero delta.  ``measure_memory`` may be True (full probe: device
    stats or tracemalloc), ``"rss"`` (cheap RSS high-water probe — the
    right choice for paper-scale entries where tracemalloc's hooks
    would dominate the measurement), or False (no probe).  When the
    probe is tracemalloc, `compile_s` includes its tracing overhead
    (both are coarse diagnostics, not gate metrics)."""
    t0 = time.perf_counter()
    peak, probe = (None, "none")
    if measure_memory:
        peak, probe = peak_memory_bytes(
            fn, cheap=(measure_memory == "rss"))  # trace+compile+warmup
    else:
        fn()
    compile_s = time.perf_counter() - t0

    walls = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        fn()
        walls.append(time.perf_counter() - t0)

    # provenance stamp defaults under explicit meta (an explicit
    # git_sha/jax_version/telemetry key in `meta` wins)
    stamped = repo_stamp(telemetry=telemetry)
    stamped.update(meta or {})
    return BenchEntry(name=name, wall_s=min(walls),
                      wall_mean_s=sum(walls) / len(walls),
                      compile_s=compile_s, repeats=len(walls),
                      cycles=cycles, peak_mem_bytes=peak, mem_probe=probe,
                      meta=stamped)


def write_bench(path: str, suite: str, entries: list, *,
                extra_meta: Optional[dict] = None) -> dict:
    """Serialise BenchEntry list to the BENCH_*.json schema."""
    import jax

    doc = {
        "schema": SCHEMA_VERSION,
        "suite": suite,
        "backend": jax.default_backend(),
        "meta": dict(extra_meta or {}),
        "entries": {e.name: e.to_json() for e in entries},
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return doc


def load_bench(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    assert doc.get("schema") == SCHEMA_VERSION, \
        f"unknown bench schema in {path}: {doc.get('schema')}"
    return doc


def check_regression(baseline: dict, entry_name: str, metric: str,
                     current: float, *, factor: float = 2.0,
                     higher_is_better: bool = True) -> tuple:
    """(ok, message) comparing `current` against the baseline metric.

    higher_is_better=True (e.g. cycles_per_sec): fail when current <
    baseline / factor.  Otherwise (e.g. wall_s): fail when current >
    baseline * factor.  A missing baseline entry passes with a notice —
    new benchmarks must not brick CI.
    """
    ent = baseline.get("entries", {}).get(entry_name)
    if ent is None or ent.get(metric) is None:
        return True, f"no baseline for {entry_name}.{metric}; skipping"
    base = float(ent[metric])
    if higher_is_better:
        ok = current >= base / factor
        rel = current / base if base else float("inf")
    else:
        ok = current <= base * factor
        rel = base / current if current else float("inf")
    msg = (f"{entry_name}.{metric}: current={current:.4g} "
           f"baseline={base:.4g} ({rel:.2f}x, gate {factor}x) "
           f"{'OK' if ok else 'REGRESSION'}")
    return ok, msg
