"""Perf-audit helper: compile a dry-run cell, list the dominant collective
/ dot contributors with loop multipliers (the §Perf iteration tool)."""

from __future__ import annotations

import re

from .hlo import (DTYPE_BYTES, _elems, _find_entry, _multipliers,
                  _op_operands, _shape_map, _split_computations, _SHAPE_RE)

__all__ = ["top_collectives", "top_dots"]


def _prep(text: str):
    comps = _split_computations(text)
    entry = _find_entry(text)
    mult = _multipliers(comps, entry)
    shapes = _shape_map(comps)

    def nbytes(name):
        sh = shapes.get(name)
        return DTYPE_BYTES[sh[0]] * _elems(sh[1]) if sh else 0.0

    return comps, mult, shapes, nbytes


def top_collectives(text: str, n: int = 10):
    comps, mult, shapes, nbytes = _prep(text)
    rows = []
    for cname, lines in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        for raw in lines:
            line = raw.strip()
            for kind in ("all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all", "collective-permute"):
                for marker in (f" {kind}(", f" {kind}-start("):
                    i = line.find(marker)
                    if i < 0:
                        continue
                    ops = _op_operands(line, marker)
                    b = sum(nbytes(o) for o in ops)
                    rows.append(dict(total=b * m, raw=b, mult=m, kind=kind,
                                     comp=cname, line=line[:120]))
    rows.sort(key=lambda r: -r["total"])
    return rows[:n]


def top_dots(text: str, n: int = 10):
    comps, mult, shapes, nbytes = _prep(text)
    rows = []
    for cname, lines in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        for raw in lines:
            line = raw.strip()
            if " dot(" not in line:
                continue
            res = _SHAPE_RE.search(line)
            ops = _op_operands(line, " dot(")
            if not res or not ops:
                continue
            res_elems = _elems(res.group(2))
            lhs = shapes.get(ops[0])
            contr = 1
            mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            if lhs and mc and mc.group(1):
                dims = lhs[1].split(",") if lhs[1] else []
                for d in mc.group(1).split(","):
                    if int(d) < len(dims):
                        contr *= int(dims[int(d)])
            f = 2.0 * res_elems * contr
            rows.append(dict(total=f * m, raw=f, mult=m, comp=cname,
                             line=line[:120]))
    rows.sort(key=lambda r: -r["total"])
    return rows[:n]
