"""Roofline terms from a compiled dry-run artifact (TPU v5e targets).

  compute    = HLO_FLOPs / (chips * peak)        peak = 197 TFLOP/s bf16
  memory     = HLO_bytes / (chips * hbm_bw)      hbm  = 819 GB/s
  collective = coll_bytes / (chips * link_bw)    link = 50 GB/s (ICI)

cost_analysis() is per-device under SPMD in current JAX; we normalise
either way via `per_device` (True: numbers already per chip).
MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) per train step, 2 N D
for inference forward — the "useful compute" yardstick.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["V5E", "RooflineTerms", "roofline_from_compiled", "model_flops"]


@dataclasses.dataclass(frozen=True)
class Chip:
    name: str
    peak_flops: float          # bf16
    hbm_bw: float              # bytes/s
    link_bw: float             # bytes/s per ICI link


V5E = Chip("tpu-v5e", 197e12, 819e9, 50e9)


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float           # per device
    hlo_bytes: float           # per device
    coll_bytes: float          # per device
    model_flops_total: float   # whole step, all devices
    chip: Chip = V5E

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / self.chip.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / self.chip.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / self.chip.link_bw

    @property
    def bottleneck(self) -> str:
        terms = dict(compute=self.t_compute, memory=self.t_memory,
                     collective=self.t_collective)
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:          # roofline lower bound
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (remat / padding / dispatch waste)."""
        total_hlo = self.hlo_flops * self.chips
        return self.model_flops_total / max(total_hlo, 1.0)

    @property
    def mfu(self) -> float:
        """Model FLOPs utilisation at the roofline bound."""
        per_dev_useful = self.model_flops_total / self.chips
        return per_dev_useful / (self.step_time * self.chip.peak_flops)

    def row(self) -> dict:
        return dict(arch=self.arch, shape=self.shape, mesh=self.mesh,
                    t_compute=self.t_compute, t_memory=self.t_memory,
                    t_collective=self.t_collective,
                    bottleneck=self.bottleneck,
                    useful=self.useful_fraction, mfu=self.mfu)


def model_flops(cfg, shape, n_params: int, active_params: Optional[int]
                = None) -> float:
    """Whole-step useful FLOPs: 6ND train, 2ND prefill, 2ND/token decode."""
    n = active_params if active_params is not None else n_params
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    # decode: one token per sequence (+ attention over the cache, which is
    # part of N-independent KV reading — counted in the memory term)
    return 2.0 * n * shape.global_batch


def roofline_from_compiled(compiled, *, arch: str, shape: str, mesh: str,
                           chips: int, model_flops_total: float,
                           hlo_text: Optional[str] = None) -> RooflineTerms:
    """Terms come from the loop-aware HLO analysis (utils.hlo) because
    XLA:CPU cost_analysis counts while bodies once — see module docstring
    there.  The numbers are per device (SPMD post-partitioning HLO)."""
    from .hlo import analyze_hlo
    text = hlo_text if hlo_text is not None else compiled.as_text()
    a = analyze_hlo(text)
    return RooflineTerms(arch=arch, shape=shape, mesh=mesh, chips=chips,
                         hlo_flops=a["flops"], hlo_bytes=a["major_bytes"],
                         coll_bytes=a["collective"]["total"],
                         model_flops_total=model_flops_total)
