"""Loop-aware optimized-HLO analysis: FLOPs, HBM-traffic proxy, and
collective bytes for the three roofline terms.

Why not compiled.cost_analysis(): XLA:CPU counts every while-loop body
ONCE, so under scan-over-layers (13-56 units) and blockwise-flash KV loops
the reported FLOPs are off by orders of magnitude (calibrated in
EXPERIMENTS.md §Roofline).  We instead parse compiled.as_text():

  1. build an instruction-name -> shape map (operands are printed without
     shapes in optimized HLO);
  2. build the computation call graph (calls=, body=, condition=,
     to_apply=) and assign every computation an execution multiplier —
     while bodies get their trip count (known_trip_count backend config,
     else the largest constant in the condition computation);
  3. FLOPs  = sum over `dot` ops of 2 * |result| * |contraction| * mult;
  4. bytes  = HBM-traffic proxy * mult:
        dot: |lhs| + |rhs| + |result|
        gather / dynamic-slice: 2 * |result|
        dynamic-update-slice: 3 * |update|      (read-modify-write)
        scatter: 3 * |updates|
     (elementwise ops are assumed fused into producers, the TPU norm);
  5. collective bytes: operand bytes of all-gather / all-reduce /
     reduce-scatter / all-to-all / collective-permute * mult.
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

__all__ = ["collective_bytes", "analyze_hlo", "DTYPE_BYTES"]

DTYPE_BYTES: Dict[str, float] = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(r"\b(" + "|".join(DTYPE_BYTES) + r")\[([\d,]*)\]")
_DEF_RE = re.compile(r"^%([\w.\-]+)\s*=\s*")
_TRIP_RE = re.compile(r"known_trip_count[^\d]*(\d+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLEE_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")


def _elems(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def _first_shape(line: str):
    m = _SHAPE_RE.search(line)
    return m.groups() if m else None


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur, buf = None, []
    for line in text.splitlines():
        if cur is None:
            if line.rstrip().endswith("{") and "->" in line:
                m = _HDR_RE.match(line.strip())
                if m:
                    cur = m.group(1)
                    buf = []
        else:
            if line.strip() == "}":
                comps[cur] = buf
                cur = None
            else:
                buf.append(line)
    return comps


def _shape_map(comps: Dict[str, List[str]]) -> Dict[str, tuple]:
    """instruction name -> (dtype, dims) of its (first/array) shape."""
    out: Dict[str, tuple] = {}
    for lines in comps.values():
        for raw in lines:
            line = raw.strip()
            m = _DEF_RE.match(line)
            if not m:
                continue
            sh = _first_shape(line)
            if sh:
                out[m.group(1)] = sh
    return out


def _multipliers(comps: Dict[str, List[str]], entry: str) -> Dict[str, float]:
    edges: Dict[str, List[Tuple[str, float]]] = {c: [] for c in comps}
    for cname, lines in comps.items():
        for line in lines:
            if " while(" in line:
                mw = re.search(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)",
                               line)
                if not mw:
                    continue
                cond, body = mw.groups()
                mt = _TRIP_RE.search(line)
                if mt:
                    n = int(mt.group(1))
                else:
                    consts = re.findall(r"constant\((\d+)\)",
                                        "\n".join(comps.get(cond, [])))
                    n = max((int(c) for c in consts), default=1)
                edges[cname].append((body, float(max(n, 1))))
                edges[cname].append((cond, float(max(n, 1) + 1)))
            else:
                for m in _CALLEE_RE.finditer(line):
                    callee = m.group(1)
                    if callee in comps:
                        edges[cname].append((callee, 1.0))
                mb = re.search(r"branch_computations=\{([^}]*)\}", line)
                if mb:
                    for callee in re.split(r",\s*", mb.group(1)):
                        callee = callee.strip().lstrip("%")
                        if callee in comps:
                            edges[cname].append((callee, 1.0))

    mult: Dict[str, float] = {c: 0.0 for c in comps}
    if entry in mult:
        mult[entry] = 1.0
    for _ in range(len(comps)):
        changed = False
        for cname, outs in edges.items():
            base = mult.get(cname, 0.0)
            if base == 0.0:
                continue
            for callee, f in outs:
                want = base * f
                if mult.get(callee, 0.0) < want:
                    mult[callee] = want
                    changed = True
        if not changed:
            break
    return mult


def _find_entry(text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
    return m.group(1) if m else ""


def _op_operands(line: str, op_marker: str) -> List[str]:
    i = line.find(op_marker)
    rest = line[i + len(op_marker):]
    close = rest.find(")")
    inner = rest[:close] if close >= 0 else rest
    return _OPERAND_RE.findall(inner)


def analyze_hlo(text: str, bf16_reductions: bool = True) -> dict:
    comps = _split_computations(text)
    entry = _find_entry(text)
    if entry not in comps:
        comps = {"<all>": text.splitlines()}
        mult = {"<all>": 1.0}
    else:
        mult = _multipliers(comps, entry)
    shapes = _shape_map(comps)

    def nbytes(name: str) -> float:
        sh = shapes.get(name)
        return DTYPE_BYTES[sh[0]] * _elems(sh[1]) if sh else 0.0

    flops = 0.0
    major_bytes = 0.0
    coll = {k: 0.0 for k in _COLL_KINDS}
    coll_counts = {k: 0 for k in _COLL_KINDS}

    for cname, lines in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0.0:
            continue
        for raw in lines:
            line = raw.strip()
            if not line.startswith("%") and not line.startswith("ROOT"):
                continue

            # ---- dot
            if " dot(" in line:
                res = _first_shape(line)
                ops = _op_operands(line, " dot(")
                if res and ops:
                    res_elems = _elems(res[1])
                    lhs_sh = shapes.get(ops[0])
                    contr = 1
                    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                                   line)
                    if lhs_sh and mc and mc.group(1):
                        lhs_dims = lhs_sh[1].split(",") if lhs_sh[1] else []
                        for d in mc.group(1).split(","):
                            if int(d) < len(lhs_dims):
                                contr *= int(lhs_dims[int(d)])
                    flops += 2.0 * res_elems * contr * m
                    major_bytes += (DTYPE_BYTES[res[0]] * res_elems
                                    + sum(nbytes(o) for o in ops[:2])) * m
                continue

            # ---- convolution (treat like dot: result * kernel-contraction)
            if " convolution(" in line:
                res = _first_shape(line)
                ops = _op_operands(line, " convolution(")
                if res and len(ops) >= 2:
                    kern = nbytes(ops[1])
                    flops += 2.0 * _elems(res[1]) * max(kern, 1.0) * m
                    major_bytes += (DTYPE_BYTES[res[0]] * _elems(res[1])
                                    + sum(nbytes(o) for o in ops[:2])) * m
                continue

            # ---- memory-major ops
            if " gather(" in line or " dynamic-slice(" in line:
                res = _first_shape(line)
                if res:
                    major_bytes += 2.0 * DTYPE_BYTES[res[0]] \
                        * _elems(res[1]) * m
                continue
            if " dynamic-update-slice(" in line:
                ops = _op_operands(line, " dynamic-update-slice(")
                if len(ops) >= 2:
                    major_bytes += 3.0 * nbytes(ops[1]) * m
                continue
            if " scatter(" in line:
                ops = _op_operands(line, " scatter(")
                if len(ops) >= 3:
                    major_bytes += 3.0 * nbytes(ops[2]) * m
                continue

            # ---- collectives
            matched = False
            for kind in _COLL_KINDS:
                for marker in (f" {kind}(", f" {kind}-start("):
                    i = line.find(marker)
                    if i < 0:
                        continue
                    ops = _op_operands(line, marker)
                    b = sum(nbytes(o) for o in ops)
                    if b == 0.0:
                        res = _first_shape(line)
                        b = (DTYPE_BYTES[res[0]] * _elems(res[1])
                             if res else 0.0)
                    # XLA:CPU widens bf16 reductions to f32 (excess
                    # precision / "_promoted" apply computations); the TPU
                    # partitioner reduces activations in bf16.  Count f32
                    # AR/RS at bf16 width in bf16-param programs.
                    if bf16_reductions and kind in ("all-reduce",
                                                    "reduce-scatter"):
                        if "promoted" in line or " f32[" in line[:60] \
                                or "(f32[" in line:
                            b /= 2.0
                    coll[kind] += b * m
                    coll_counts[kind] += 1
                    matched = True
                    break
                if matched:
                    break

    return dict(flops=flops, major_bytes=major_bytes,
                collective=dict(coll, total=sum(coll.values()),
                                counts=coll_counts))


def collective_bytes(text: str) -> Dict[str, float]:
    return analyze_hlo(text)["collective"]
