"""FabricModel (repro.dist.topology_aware): alpha-beta-with-hops
collective estimates — monotonicity, ring/direct crossover on low- vs
high-diameter fabrics, and topology sensitivity of the latency term."""

import numpy as np
import pytest

from repro.core import build_slimfly
from repro.core.topologies import build_dragonfly, build_fattree3
from repro.dist.topology_aware import FabricModel


@pytest.fixture(scope="module")
def sf7():
    return FabricModel(build_slimfly(7))


@pytest.fixture(scope="module")
def ft3():
    return FabricModel(build_fattree3(p=8))


def group_of(fm, k=32):
    return np.arange(0, fm.n_nodes, max(1, fm.n_nodes // k))[:k]


# ------------------------------------------------------------ structure --
def test_estimates_have_both_algorithms(sf7):
    est = sf7.estimate("all_reduce", 1e6, group_of(sf7))
    assert set(est) == {"ring", "direct", "best"}
    assert est["ring"].algorithm == "ring"
    assert est["direct"].algorithm == "direct"
    assert est["best"].time_s == min(est["ring"].time_s,
                                     est["direct"].time_s)
    for e in est.values():
        assert np.isfinite(e.time_s) and e.time_s > 0
        assert e.time_s == pytest.approx(e.latency_s + e.bandwidth_s)


def test_trivial_groups_cost_nothing(sf7):
    for k in (0, 1):
        est = sf7.estimate("all_reduce", 1e9, np.arange(k))
        assert est["best"].time_s == 0.0


# ---------------------------------------------------------- monotonicity --
@pytest.mark.parametrize("collective", ["all_reduce", "all_to_all",
                                        "all_gather", "reduce_scatter"])
def test_estimates_monotone_in_payload(sf7, ft3, collective):
    payloads = np.logspace(2, 10, 17)          # 100 B .. 10 GB
    for fm in (sf7, ft3):
        g = group_of(fm)
        for algo in ("ring", "direct", "best"):
            times = [fm.estimate(collective, p, g)[algo].time_s
                     for p in payloads]
            assert all(b > a for a, b in zip(times, times[1:])), (
                collective, algo, times)


def test_estimates_monotone_in_group_size(sf7):
    """More participants => more time, either algorithm (fixed payload)."""
    for algo in ("ring", "direct"):
        times = [sf7.estimate("all_reduce", 1e8,
                              group_of(sf7, k))[algo].time_s
                 for k in (8, 16, 32, 64)]
        assert all(b > a for a, b in zip(times, times[1:])), (algo, times)


# ------------------------------------------------------ ring vs direct --
def test_direct_wins_small_payload_on_diameter2_slimfly(sf7):
    """On a diameter-2 Slim Fly a latency-bound (small) collective should
    go one-shot: direct pays alpha + <=2 hops once; the ring pays
    2(k-1) alphas."""
    assert sf7.topo.diameter() == 2
    est = sf7.estimate("all_reduce", 4 * 1024, group_of(sf7, 32))
    assert est["direct"].time_s < est["ring"].time_s
    assert est["best"].algorithm == "direct"


def test_ring_wins_asymptotically_on_fattree(ft3):
    """Bandwidth-bound (large) collectives: the ring moves 2(k-1)/k * P
    per NIC vs (k-1) * P for direct — ring wins on ANY fabric once the
    payload is big enough, fat tree included."""
    g = group_of(ft3, 32)
    small = ft3.estimate("all_reduce", 1024, g)
    large = ft3.estimate("all_reduce", 10e9, g)
    assert large["best"].algorithm == "ring"
    assert large["ring"].time_s < large["direct"].time_s
    # and the crossover exists: direct was winning down at 1 KiB
    assert small["best"].algorithm == "direct"


def test_single_crossover_direct_then_ring(sf7, ft3):
    """On every fabric the payload axis splits into exactly two regimes:
    latency-bound (direct) below a single crossover, bandwidth-bound
    (ring) above it — the decision never flips back."""
    payloads = np.logspace(1, 11, 41)
    for fm in (sf7, ft3):
        g = group_of(fm, 32)
        algos = [fm.estimate("all_reduce", p, g)["best"].algorithm
                 for p in payloads]
        assert algos[0] == "direct" and algos[-1] == "ring"
        flips = sum(a != b for a, b in zip(algos, algos[1:]))
        assert flips == 1, algos


# ------------------------------------------------------ hops sensitivity --
def test_latency_term_tracks_hop_count(sf7, ft3):
    """Same group size + payload: the fabric with more hops per pair
    pays more latency for the direct algorithm."""
    df = FabricModel(build_dragonfly(h=3))
    k = 32
    ests = {}
    for name, fm in [("sf", sf7), ("df", df), ("ft", ft3)]:
        e = fm.estimate("all_reduce", 1024, group_of(fm, k))["direct"]
        ests[name] = e
    assert ests["sf"].mean_hops <= 2.0
    assert ests["ft"].mean_hops > ests["sf"].mean_hops
    assert ests["ft"].latency_s > ests["sf"].latency_s


def test_colocated_group_is_cheaper(sf7):
    """p endpoints share a router (0 hops): a rack-local group must cost
    less in latency than a scattered one of equal size."""
    p = sf7.topo.p
    local = np.arange(2 * p)                      # two adjacent routers
    spread = group_of(sf7, 2 * p)
    e_local = sf7.estimate("all_reduce", 1e6, local)
    e_spread = sf7.estimate("all_reduce", 1e6, spread)
    assert e_local["direct"].latency_s <= e_spread["direct"].latency_s
