"""Lane-batched sweep engine (DESIGN.md §10): per-lane bit-exactness
against the sequential loop, mixed (rate x seed x failure-mask) lanes,
stacking/ragged guards, closed-loop lane sweeps, and the lane axis of
the allocation kernels."""

import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import cached_slimfly
from repro.core.resiliency import failure_edge_sample
from repro.kernels import alloc_rounds, ugal_select
from repro.sim import (SimConfig, SimTables, make_traffic, simulate,
                       sweep_run_workload, sweep_simulate)
from repro.sim.workloads import (WorkloadSimConfig, ring_all_reduce,
                                 run_workload)


def _assert_same(a, b):
    assert a.delivered == b.delivered
    assert a.injected == b.injected
    assert a.dropped_at_source == b.dropped_at_source
    assert a.avg_latency == b.avg_latency
    assert a.accepted_load == b.accepted_load
    np.testing.assert_array_equal(a.per_cycle_delivered,
                                  b.per_cycle_delivered)
    np.testing.assert_array_equal(a.per_cycle_in_flight,
                                  b.per_cycle_in_flight)


@pytest.mark.parametrize("mode", ["min", "val", "ugal_l", "ecmp"])
def test_sweep_bitexact_vs_sequential(mode):
    """A rate+seed sweep is bit-identical, lane for lane, to the
    sequential per-point loop — across every routing mode."""
    tables = SimTables.build(cached_slimfly(5), ecmp=(mode == "ecmp"))
    tr = make_traffic(tables, "uniform")
    cfg = SimConfig(cycles=50, warmup=10, mode=mode)
    rates, seeds = [0.15, 0.35, 0.6], [3, 4, 5]

    swept = sweep_simulate(tables, tr, cfg, rates=rates, seeds=seeds)
    assert len(swept) == 3
    for r, s, got in zip(rates, seeds, swept):
        want = simulate(tables, tr, dataclasses.replace(
            cfg, injection_rate=r, seed=s))
        _assert_same(got, want)


def test_sweep_mixed_failure_lanes():
    """Lanes may vary rate AND seed AND failure mask at once: the
    degraded tables ride the lane axis as operands of one compiled
    scan, and every lane still matches its own sequential run."""
    topo = cached_slimfly(5)
    healthy = SimTables.build(topo)
    fe1 = failure_edge_sample(topo, 0.05, np.random.default_rng(1))
    fe2 = failure_edge_sample(topo, 0.15, np.random.default_rng(2))
    lanes = [healthy,
             SimTables.build(topo, failed_edges=fe1),
             SimTables.build(topo, failed_edges=fe2)]
    tr = make_traffic(healthy, "uniform")
    cfg = SimConfig(cycles=50, warmup=10, mode="ugal_l")
    rates, seeds = [0.2, 0.4, 0.3], [0, 1, 2]

    swept = sweep_simulate(lanes, tr, cfg, rates=rates, seeds=seeds)
    for tab, r, s, got in zip(lanes, rates, seeds, swept):
        want = simulate(tab, tr, dataclasses.replace(
            cfg, injection_rate=r, seed=s))
        _assert_same(got, want)


def test_sweep_single_lane_degenerates():
    """L=1 must take exactly today's single-lane path."""
    tables = SimTables.build(cached_slimfly(5))
    tr = make_traffic(tables, "uniform")
    cfg = SimConfig(cycles=40, warmup=10, mode="min", seed=9)
    swept = sweep_simulate(tables, tr, cfg, rates=[0.3])
    assert len(swept) == 1
    _assert_same(swept[0], simulate(tables, tr, dataclasses.replace(
        cfg, injection_rate=0.3)))


def test_sweep_ragged_lanes_raise():
    tables = SimTables.build(cached_slimfly(5))
    tr = make_traffic(tables, "uniform")
    cfg = SimConfig(cycles=20)
    with pytest.raises(ValueError, match="ragged"):
        sweep_simulate(tables, tr, cfg, rates=[0.1, 0.2], seeds=[1, 2, 3])
    topo = cached_slimfly(5)
    fe = failure_edge_sample(topo, 0.1, np.random.default_rng(0))
    lanes = [tables, SimTables.build(topo, failed_edges=fe)]
    with pytest.raises(ValueError, match="ragged"):
        sweep_simulate(lanes, tr, cfg, rates=[0.1, 0.2, 0.3])


def test_stack_pads_ecmp_and_validates():
    topo = cached_slimfly(5)
    a = SimTables.build(topo, ecmp=True)
    fe = failure_edge_sample(topo, 0.10, np.random.default_rng(3))
    b = SimTables.build(topo, ecmp=True, failed_edges=fe)
    stacked = SimTables.stack([a, b])
    assert stacked.lanes == 2
    width = max(a.ecmp_ports.shape[-1], b.ecmp_ports.shape[-1])
    assert stacked.ecmp_ports.shape == (2,) + a.ecmp_ports.shape[:2] + \
        (width,)
    # lane() round-trips the unpadded prefix
    np.testing.assert_array_equal(
        stacked.lane(1).ecmp_ports[..., :b.ecmp_ports.shape[-1]],
        b.ecmp_ports)
    np.testing.assert_array_equal(stacked.lane(0).nbr, a.nbr)
    # mixing ecmp and non-ecmp lanes is a shape error
    with pytest.raises(AssertionError, match="ecmp"):
        SimTables.stack([a, SimTables.build(topo)])
    # different fabrics don't stack
    seven = SimTables.build(cached_slimfly(7), ecmp=True)
    with pytest.raises(AssertionError):
        SimTables.stack([a, seven])


def test_failure_mask_sweeps_share_one_compile():
    """In the mask-varying lane path the tables are traced operands
    keyed STRUCTURALLY: a second sweep over entirely different failure
    samples of the same topology must reuse the first sweep's
    executable (the compile-tax fix that makes mask sweeps cheap)."""
    from repro.sim import sweep as _sweep

    topo = cached_slimfly(5)
    healthy = SimTables.build(topo)
    rng = np.random.default_rng(5)
    masks = [failure_edge_sample(topo, 0.10, rng) for _ in range(3)]
    lanes_a = [healthy, SimTables.build(topo, failed_edges=masks[0])]
    lanes_b = [SimTables.build(topo, failed_edges=masks[1]),
               SimTables.build(topo, failed_edges=masks[2])]
    tr = make_traffic(healthy, "uniform")
    cfg = SimConfig(cycles=20, warmup=0, mode="min")

    _sweep._SWEEP_CACHE.clear()
    sweep_simulate(lanes_a, tr, cfg, rates=[0.2, 0.3])
    assert len(_sweep._SWEEP_CACHE) == 1
    res = sweep_simulate(lanes_b, tr, cfg, rates=[0.2, 0.3])
    assert len(_sweep._SWEEP_CACHE) == 1, \
        "a different mask set recompiled the mask-varying sweep runner"
    # and the structurally-shared executable still computes per-mask
    # exact results
    want = simulate(lanes_b[1], tr, dataclasses.replace(
        cfg, injection_rate=0.3))
    _assert_same(res[1], want)


def test_sweep_workload_lanes_bitexact():
    """Closed-loop lanes (healthy + degraded tables, distinct seeds)
    reproduce sequential run_workload results exactly."""
    topo = cached_slimfly(5)
    healthy = SimTables.build(topo)
    fe = failure_edge_sample(topo, 0.10, np.random.default_rng(7))
    degraded = SimTables.build(topo, failed_edges=fe)
    wl = ring_all_reduce(8, 2)
    cfg = WorkloadSimConfig(mode="ugal_l", chunk=64)

    swept = sweep_run_workload([healthy, degraded], wl, cfg,
                               seeds=[0, 1])
    for tab, s, got in zip([healthy, degraded], [0, 1], swept):
        want = run_workload(tab, wl, dataclasses.replace(cfg, seed=s))
        assert got.completed and want.completed
        assert got.makespan == want.makespan
        assert got.flits_delivered == want.flits_delivered
        np.testing.assert_array_equal(got.msg_done, want.msg_done)
        np.testing.assert_array_equal(got.msg_start, want.msg_start)
        np.testing.assert_array_equal(got.msg_delivered,
                                      want.msg_delivered)
        # batched loop may run longer than this lane needed; the
        # delivered-flit stream agrees on the common prefix and is
        # silent afterwards
        n = len(want.per_cycle_delivered)
        np.testing.assert_array_equal(got.per_cycle_delivered[:n],
                                      want.per_cycle_delivered)
        assert got.per_cycle_delivered[n:].sum() == 0


def test_sweep_workload_seed_sensitive_placement_guarded():
    """placement='random' places differently per seed; a multi-seed
    lane sweep must refuse rather than silently place every lane with
    one seed (which would break the sequential-equivalence contract).
    Passing ep_of_rank explicitly pins the placement and is allowed."""
    from repro.sim.workloads.mapping import place_ranks

    tables = SimTables.build(cached_slimfly(5))
    wl = ring_all_reduce(8, 2)
    cfg = WorkloadSimConfig(mode="min", chunk=64, placement="random")
    with pytest.raises(ValueError, match="placement"):
        sweep_run_workload(tables, wl, cfg, seeds=[0, 1])
    pin = place_ranks(tables, wl.n_ranks, "random", seed=3)
    res = sweep_run_workload(tables, wl, cfg, seeds=[0, 1],
                             ep_of_rank=pin)
    for s, got in zip([0, 1], res):
        want = run_workload(tables, wl, dataclasses.replace(cfg, seed=s),
                            ep_of_rank=pin)
        assert got.makespan == want.makespan


def test_sweep_workload_single_lane_degenerates():
    tables = SimTables.build(cached_slimfly(5))
    wl = ring_all_reduce(8, 2)
    cfg = WorkloadSimConfig(mode="min", chunk=64)
    swept = sweep_run_workload(tables, wl, cfg)
    want = run_workload(tables, wl, cfg)
    assert len(swept) == 1
    assert swept[0].makespan == want.makespan
    assert swept[0].cycles_run == want.cycles_run


def test_sweep_pallas_matches_ref_per_lane():
    """kernel_path='pallas' under the lane vmap (the pallas grid grows
    a lane dimension) stays bit-identical to the jnp oracle path."""
    tables = SimTables.build(cached_slimfly(5))
    tr = make_traffic(tables, "uniform")
    cfg = SimConfig(cycles=30, warmup=5, mode="ugal_l",
                    kernel_path="ref")
    rates = [0.2, 0.5]
    ref = sweep_simulate(tables, tr, cfg, rates=rates)
    pal = sweep_simulate(tables, tr, dataclasses.replace(
        cfg, kernel_path="pallas"), rates=rates)
    for a, b in zip(ref, pal):
        _assert_same(a, b)


def test_alloc_rounds_lane_axis():
    """The kernel dispatchers accept a leading lane axis: lane-batched
    ref == lane-batched pallas == per-lane single calls."""
    rng = np.random.default_rng(0)
    L, N, P, V, PE, W = 3, 7, 5, 2, 3, 4
    PV = P * V
    NQ, R = N * PV, N * PV + N * PE
    names = ["out_net", "ej_net", "space_net", "count_net",
             "out_src", "ej_src", "space_src", "count_src"]
    shapes = [(L, N, PV, W), (L, N, PV, W), (L, N, PV, W), (L, N, PV),
              (L, N, PE, W), (L, N, PE, W), (L, N, PE, W), (L, N, PE)]
    los = [-1, 0, 0, 0, -1, 0, 0, 0]
    his = [P, 2, 2, 5, P, 2, 2, 5]
    args = [jnp.asarray(rng.integers(lo, hi, sh).astype(np.int32))
            for lo, hi, sh in zip(los, his, shapes)]
    epr = jnp.arange(N, dtype=jnp.int32)
    kw = dict(W=W, P=P, V=V, PE=PE, p_budget=PE, NQ=NQ, R=R)

    ref_out = alloc_rounds(jnp.int32(7), *args, epr, **kw,
                           use_pallas=False)
    pal_out = alloc_rounds(jnp.int32(7), *args, epr, **kw,
                           use_pallas=True)
    for a, b in zip(ref_out, pal_out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for lane in range(L):
        one = alloc_rounds(jnp.int32(7), *[x[lane] for x in args], epr,
                           **kw, use_pallas=False)
        for a, b in zip(ref_out, one):
            np.testing.assert_array_equal(np.asarray(a[lane]),
                                          np.asarray(b))
    # per-lane cycles are honoured when cycle itself is lane-batched
    cyc = jnp.asarray([7, 8, 9], jnp.int32)
    ref_c = alloc_rounds(cyc, *args, epr, **kw, use_pallas=False)
    one8 = alloc_rounds(jnp.int32(8), *[x[1] for x in args], epr, **kw,
                        use_pallas=False)
    for a, b in zip(ref_c, one8):
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b))


def test_ugal_select_lane_axis():
    rng = np.random.default_rng(1)
    L, E, C = 2, 64, 4
    unreach, big = 1 << 14, 1 << 30
    lm = jnp.asarray(rng.choice([1, 2, unreach], (L, E)).astype(np.int32))
    lv = jnp.asarray(
        rng.choice([2, 3, 4, unreach], (L, E, C)).astype(np.int32))
    om = jnp.asarray(rng.integers(0, 1 << 20, (L, E)).astype(np.int32))
    ov = jnp.asarray(rng.integers(0, 1 << 20, (L, E, C)).astype(np.int32))
    kw = dict(ugal_g=False, unreach=unreach, big=big)
    ref_out = ugal_select(lm, lv, om, ov, **kw, use_pallas=False)
    pal_out = ugal_select(lm, lv, om, ov, **kw, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(ref_out), np.asarray(pal_out))
    for lane in range(L):
        one = ugal_select(lm[lane], lv[lane], om[lane], ov[lane], **kw,
                          use_pallas=False)
        np.testing.assert_array_equal(np.asarray(ref_out[lane]),
                                      np.asarray(one))
