"""Multi-tenant job layer (DESIGN.md §11): the 1-job arrival-0 path of
the generalized engine is bit-exact vs `run_workload` (golden-pinned),
arrival cycles gate injection exactly, the admission queue serializes
endpoint conflicts (FIFO head-of-line vs backfill), and `place_jobs`
carves disjoint per-job placements out of the policy orders."""

import numpy as np
import pytest

from repro.core import build_slimfly
from repro.core.layout import make_layout
from repro.sim import SimTables
from repro.sim.workloads import (
    JOB_PLACEMENTS,
    Job,
    WorkloadSimConfig,
    all_to_all,
    place_jobs,
    ring_all_reduce,
    run_jobs,
    run_workload,
    stencil,
)


@pytest.fixture(scope="module")
def sf5_tables():
    return SimTables.build(build_slimfly(5))


# ---------------------------------------------------------------------------
# single-job degenerate: bit-exact vs run_workload, golden-pinned
# ---------------------------------------------------------------------------

# Golden outcomes of the single-job closed-loop path on SF q=5,
# captured from the pre-job-layer engine (PR 5 tree).  The multi-job
# refactor must keep a 1-job arrival-0 run bit-identical: same
# makespan, same per-message start/done cycles, same delivered flits.
# cycles_run pins the TRIMMED value (== makespan; the pre-fix engine
# reported the chunk-rounded 256/192/100 here).  Caveat: route RNG
# ties these values to the jax PRNG implementation — a jax upgrade may
# legitimately shift them (re-pin if so, like test_engine_scaling's
# golden).
_GOLDEN = [
    # (workload builder, cfg kwargs, makespan, flits, done_sum, start_sum)
    (lambda: ring_all_reduce(16, 8),
     dict(mode="min", placement="linear", chunk=128, seed=0),
     250.0, 3840, 61845, 57855),
    (lambda: ring_all_reduce(12, 5),
     dict(mode="ugal_l", placement="spread", chunk=96, seed=3),
     182.0, 1320, 24615, 22478),
    (lambda: stencil((4, 4), 8, iters=2),
     dict(mode="min", placement="blocked", chunk=100, seed=1),
     98.0, 1024, 6646, 4332),
]


@pytest.mark.parametrize("case", range(len(_GOLDEN)))
def test_golden_single_job_outcomes(sf5_tables, case):
    wl_fn, kw, makespan, flits, done_sum, start_sum = _GOLDEN[case]
    r = run_workload(sf5_tables, wl_fn(), WorkloadSimConfig(**kw))
    assert r.completed
    assert r.makespan == makespan
    assert r.cycles_run == int(makespan)          # trimmed, not rounded
    assert r.flits_delivered == flits
    assert int(r.msg_done.sum()) == done_sum
    assert int(r.msg_start.sum()) == start_sum


def test_single_job_bitexact_vs_run_workload(sf5_tables):
    """run_jobs with one arrival-0 job under `pack` must reproduce
    run_workload under `linear` placement bit-for-bit (same compiled
    step, admit gate all-true)."""
    wl = ring_all_reduce(16, 8)
    cfg = WorkloadSimConfig(mode="min", chunk=128, seed=0)
    r = run_workload(sf5_tables, wl, cfg)
    mj = run_jobs(sf5_tables, [Job("solo", wl, arrival=0)], cfg,
                  policy="pack")
    jr = mj.jobs[0]
    assert mj.completed and jr.completed
    assert mj.makespan == r.makespan
    assert mj.cycles_run == r.cycles_run
    assert mj.flits_delivered == r.flits_delivered
    np.testing.assert_array_equal(jr.msg_start, r.msg_start)
    np.testing.assert_array_equal(jr.msg_done, r.msg_done)
    np.testing.assert_array_equal(jr.ep_of_rank, r.ep_of_rank)
    np.testing.assert_array_equal(mj.per_cycle_delivered,
                                  r.per_cycle_delivered)


# ---------------------------------------------------------------------------
# arrival gating and conservation
# ---------------------------------------------------------------------------

def test_arrival_gates_injection_exactly(sf5_tables):
    """A lone job arriving at cycle a starts injecting exactly at a
    (admitted at t=0 with admit=arrival, endpoints free) and its JCT
    excludes the pre-arrival idle time."""
    wl = ring_all_reduce(8, 4)
    cfg = WorkloadSimConfig(mode="min", chunk=64, seed=0)
    base = run_jobs(sf5_tables, [Job("j", wl, 0)], cfg, policy="pack")
    late = run_jobs(sf5_tables, [Job("j", wl, 37)], cfg, policy="pack")
    jb, jl = base.jobs[0], late.jobs[0]
    assert jl.admit_cycle == 37 and jl.start >= 37
    assert (jl.msg_start >= 37).all()
    assert jl.queue_delay == 0
    # same DAG alone on an idle fabric: service time matches the
    # arrival-0 run up to route-RNG phase differences; the makespan
    # accounting must shift with the arrival
    assert late.makespan >= 37 + 1
    assert jl.jct == jl.done - 37
    assert abs(jl.jct - jb.jct) <= 0.25 * jb.jct


def test_multijob_conservation(sf5_tables):
    """Every job in a 3-tenant mix drains its DAG; fabric-level
    delivered flits are the sum of the jobs' totals."""
    jobs = [Job("ring", ring_all_reduce(12, 4), 0),
            Job("a2a", all_to_all(8, 2), 40),
            Job("st", stencil((4, 4), 4, iters=1), 80)]
    mj = run_jobs(sf5_tables, jobs, WorkloadSimConfig(mode="min", chunk=64),
                  policy="spread")
    assert mj.completed
    total = sum(j.workload.total_flits for j in jobs)
    assert mj.flits_delivered == total
    assert int(mj.per_cycle_delivered.sum()) == total
    assert mj.cycles_run == int(mj.makespan)
    for job, jr in zip(jobs, mj.jobs):
        assert jr.completed
        assert jr.flits_delivered == job.workload.total_flits
        assert (jr.msg_done > jr.msg_start).all()
        assert jr.start >= job.arrival


# ---------------------------------------------------------------------------
# admission queue
# ---------------------------------------------------------------------------

def test_admission_serializes_endpoint_conflict(sf5_tables):
    """Two jobs pinned to the SAME endpoints run strictly one after the
    other: the second admits at a chunk boundary at or after the first
    completes, and starts no earlier than its admission."""
    wl = ring_all_reduce(8, 4)
    cfg = WorkloadSimConfig(mode="min", chunk=64, seed=0)
    pl = place_jobs(sf5_tables, [Job("A", wl, 0)], "pack")[0]
    mj = run_jobs(sf5_tables, [Job("A", wl, 0), Job("B", wl, 0)], cfg,
                  placements=[pl, pl])
    a, b = mj.jobs
    assert mj.completed
    assert b.admit_cycle >= a.done
    assert b.admit_cycle % cfg.chunk == 0        # boundary granularity
    assert b.start >= b.admit_cycle
    assert (b.msg_start >= b.admit_cycle).all()
    assert b.queue_delay > 0
    assert mj.makespan == b.done


def test_fifo_blocks_backfill_admits(sf5_tables):
    """C's endpoints are free, but under FIFO it waits behind the
    queued head-of-line job B; backfill admits C immediately."""
    wl = ring_all_reduce(8, 4)
    c_wl = all_to_all(6, 2)
    cfg = WorkloadSimConfig(mode="min", chunk=64, seed=0)
    pl = place_jobs(sf5_tables, [Job("A", wl, 0), Job("B", wl, 0),
                                 Job("C", c_wl, 0)], "pack")
    placements = [pl[0], pl[0], pl[2]]           # B conflicts with A
    jobs = [Job("A", wl, 0), Job("B", wl, 0), Job("C", c_wl, 0)]
    fifo = run_jobs(sf5_tables, jobs, cfg, placements=placements,
                    queue="fifo")
    back = run_jobs(sf5_tables, jobs, cfg, placements=placements,
                    queue="backfill")
    assert fifo.completed and back.completed
    assert back.job("C").admit_cycle == 0        # arrival, not blocked
    assert fifo.job("C").admit_cycle > 0         # head-of-line blocked
    assert fifo.job("B").queue_delay > 0
    assert back.job("B").queue_delay > 0


def test_run_jobs_validates_inputs(sf5_tables):
    wl = ring_all_reduce(8, 4)
    with pytest.raises(ValueError, match="sorted by arrival"):
        run_jobs(sf5_tables, [Job("A", wl, 10), Job("B", wl, 0)])
    with pytest.raises(ValueError, match="unknown queue"):
        run_jobs(sf5_tables, [Job("A", wl, 0)], queue="lifo")
    with pytest.raises(ValueError, match="unknown job placement"):
        place_jobs(sf5_tables, [Job("A", wl, 0)], "best-fit")


# ---------------------------------------------------------------------------
# placement policies
# ---------------------------------------------------------------------------

def test_place_jobs_disjoint_and_injective(sf5_tables):
    jobs = [Job("a", ring_all_reduce(12, 4), 0),
            Job("b", all_to_all(8, 2), 0),
            Job("c", stencil((4, 4), 4, iters=1), 0)]
    for policy in JOB_PLACEMENTS:
        pls = place_jobs(sf5_tables, jobs, policy)
        seen = set()
        for job, eps in zip(jobs, pls):
            assert len(eps) == job.n_ranks
            assert len(np.unique(eps)) == len(eps)
            assert eps.min() >= 0 and eps.max() < sf5_tables.n_endpoints
            assert not (set(eps.tolist()) & seen), policy
            seen |= set(eps.tolist())


def test_place_jobs_pack_is_contiguous(sf5_tables):
    jobs = [Job("a", ring_all_reduce(8, 4), 0),
            Job("b", all_to_all(6, 2), 0)]
    pls = place_jobs(sf5_tables, jobs, "pack")
    np.testing.assert_array_equal(pls[0], np.arange(8))
    np.testing.assert_array_equal(pls[1], np.arange(8, 8 + 6))


def test_place_jobs_rack_aware_separates_racks(sf5_tables):
    layout = make_layout(sf5_tables.topo)
    jobs = [Job("a", ring_all_reduce(6, 4), 0),
            Job("b", all_to_all(6, 2), 0)]
    pls = place_jobs(sf5_tables, jobs, "rack-aware")
    racks = [set(layout.rack_of[sf5_tables.ep_router[eps]].tolist())
             for eps in pls]
    assert not (racks[0] & racks[1]), racks


def test_place_jobs_wraps_when_fabric_full(sf5_tables):
    """Demand beyond the fabric wraps modulo n_endpoints: the wrapped
    job overlaps the first (the admission queue then serialises it)."""
    n_ep = sf5_tables.n_endpoints
    k = (2 * n_ep) // 3
    jobs = [Job("a", all_to_all(k, 1), 0), Job("b", all_to_all(k, 1), 0)]
    pls = place_jobs(sf5_tables, jobs, "pack")
    assert set(pls[0].tolist()) & set(pls[1].tolist())
    assert len(np.unique(pls[1])) == k
