"""Physical layout (§VI-A) and cost/power model (§VI-B/C, Table IV)."""

import numpy as np
import pytest

from repro.core import build_slimfly
from repro.core.cost import network_cost, network_power, router_cost
from repro.core.layout import make_layout
from repro.core.topologies import build_dragonfly, build_fattree3, build_torus


def test_slimfly_layout_structure():
    """Fig 10: q racks, every pair of racks joined by exactly 2q channels,
    identical intra-rack cable pattern."""
    q = 19
    topo = build_slimfly(q)
    lay = make_layout(topo)
    assert lay.n_racks == q
    inter = lay.inter_rack_channels()
    off = inter[np.triu_indices(q, 1)]
    assert (off == 2 * q).all()          # paper: 2q inter-group cables
    # identical racks: same number of intra-rack cables everywhere
    e = topo.edge_list()
    ra, rb = lay.rack_of[e[:, 0]], lay.rack_of[e[:, 1]]
    intra_counts = np.bincount(ra[ra == rb], minlength=q)
    assert len(set(intra_counts.tolist())) == 1


def test_slimfly_rack_size_example():
    """§VI-A example: q=19 => 19 racks of 38 routers (570 endpoints)."""
    topo = build_slimfly(19)
    lay = make_layout(topo)
    sizes = np.bincount(lay.rack_of)
    assert (sizes == 38).all()
    assert sizes[0] * topo.p == 570


def test_table4_slimfly_cost_power():
    """Table IV: SF q=19 at billed radix 43: $1,033/node, 8.02 W/node.
    We accept +-7% on cost (cable-length estimation differs in the meter
    details) and +-1% on power."""
    topo = build_slimfly(19)
    c = network_cost(topo, router_radix=43)
    p = network_power(topo, router_radix=43)
    assert abs(c["per_endpoint"] - 1033) / 1033 < 0.07
    assert abs(p["per_endpoint_w"] - 8.02) / 8.02 < 0.01


def test_table4_dragonfly_cost_power():
    """Table IV: DF k=27 (h=7): $1,342-1,438/node band, 10.8-10.9 W/node."""
    topo = build_dragonfly(h=7)
    c = network_cost(topo)
    p = network_power(topo)
    assert 1150 < c["per_endpoint"] < 1600
    assert abs(p["per_endpoint_w"] - 10.9) / 10.9 < 0.02


def test_slimfly_cheaper_than_dragonfly():
    """The headline: SF ~25% more cost- and power-effective than DF at
    comparable N and identical radix (paper §VI-B4, §VI-C)."""
    sf = build_slimfly(19)                 # N=10830, billed k=43
    df = build_dragonfly(h=11, a=22, p=11)  # k=43, N=26 862 — same radix
    sf_c = network_cost(sf, router_radix=43)["per_endpoint"]
    df_c = network_cost(df, router_radix=43)["per_endpoint"]
    assert sf_c < df_c * 0.85
    sf_p = network_power(sf, router_radix=43)["per_endpoint_w"]
    df_p = network_power(df, router_radix=43)["per_endpoint_w"]
    assert sf_p < df_p * 0.85


def test_torus_all_electric():
    topo = build_torus(6, 3)
    lay = make_layout(topo)
    is_fiber, length = lay.cable_lengths()
    assert not is_fiber.any()


def test_router_cost_linear():
    assert router_cost(43) == pytest.approx(350.4 * 43 - 892.3)


def test_generic_layout_covers_everything():
    for topo in [build_fattree3(p=6), build_dragonfly(h=3)]:
        lay = make_layout(topo)
        assert lay.rack_of.shape == (topo.n_routers,)
        assert lay.rack_of.max() < lay.n_racks
        c = network_cost(topo)
        assert c["total"] > 0 and np.isfinite(c["total"])
