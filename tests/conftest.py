"""Shared helpers: paper-scale Slim Fly topologies are expensive to
build (q=17 => 578 routers), so tests share one instance per q."""

import functools

from repro.core import build_slimfly


@functools.lru_cache(maxsize=None)
def cached_slimfly(q: int, p=None):
    return build_slimfly(q) if p is None else build_slimfly(q, p=p)
