"""Paper-scale engine tests (DESIGN.md §9): ref-vs-pallas bit
equivalence, dtype-packing overflow guards, and the repro.bench
regression harness."""

import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import cached_slimfly
from repro.bench import (bench_callable, check_regression, load_bench,
                         write_bench)
from repro.core.resiliency import failure_edge_sample
from repro.kernels import alloc_rounds, ugal_select
from repro.kernels.alloc import alloc_rounds_pallas, ugal_select_pallas
from repro.kernels.ref import KSHIFT, alloc_rounds_ref, ugal_select_ref
from repro.sim import SimConfig, SimTables, make_traffic, simulate
from repro.sim.packed import (HOPS_MAX, MAX_MSGS, MAX_ROUTERS,
                              bump_hops_word, pack_record, unpack_record)


# ---------------------------------------------------------- equivalence --
def _assert_same_result(ra, rb):
    assert ra.delivered == rb.delivered
    assert ra.injected == rb.injected
    assert ra.dropped_at_source == rb.dropped_at_source
    assert ra.avg_latency == rb.avg_latency
    np.testing.assert_array_equal(ra.per_cycle_delivered,
                                  rb.per_cycle_delivered)
    np.testing.assert_array_equal(ra.per_cycle_in_flight,
                                  rb.per_cycle_in_flight)


def _run_both(tables, traffic, mode, cycles=60):
    cfg = SimConfig(injection_rate=0.35, cycles=cycles, warmup=10,
                    mode=mode, seed=3, kernel_path="ref")
    r_ref = simulate(tables, traffic, cfg)
    r_pal = simulate(tables, traffic,
                     dataclasses.replace(cfg, kernel_path="pallas"))
    _assert_same_result(r_ref, r_pal)
    assert r_ref.delivered > 0
    return r_ref


@pytest.mark.parametrize("mode", ["min", "ugal_l"])
def test_pallas_matches_ref_q5_healthy(mode):
    tables = SimTables.build(cached_slimfly(5))
    _run_both(tables, make_traffic(tables, "uniform"), mode)


@pytest.mark.parametrize("mode", ["min", "ugal_l"])
def test_pallas_matches_ref_q5_degraded(mode):
    """10% failed links (routes re-converged): the engine's dead-port
    handling must be identical on both kernel paths."""
    topo = cached_slimfly(5)
    fe = failure_edge_sample(topo, 0.10, np.random.default_rng(1))
    tables = SimTables.build(topo, failed_edges=fe)
    _run_both(tables, make_traffic(tables, "uniform"), mode)


def test_pallas_matches_ref_q7():
    tables = SimTables.build(cached_slimfly(7))
    _run_both(tables, make_traffic(tables, "uniform"), "ugal_l",
              cycles=40)


def test_alloc_rounds_kernel_matches_ref():
    """Unit-level: random request tensors, including a router count that
    exercises the pallas row padding."""
    rng = np.random.default_rng(0)
    N, P, V, PE, W = 11, 5, 2, 3, 4
    PV = P * V
    NQ, R = N * PV, N * PV + N * PE
    shapes = dict(
        out_net=rng.integers(-1, P, (N, PV, W)),
        ej_net=rng.integers(0, 2, (N, PV, W)),
        space_net=rng.integers(0, 2, (N, PV, W)),
        count_net=rng.integers(0, 5, (N, PV)),
        out_src=rng.integers(-1, P, (N, PE, W)),
        ej_src=rng.integers(0, 2, (N, PE, W)),
        space_src=rng.integers(0, 2, (N, PE, W)),
        count_src=rng.integers(0, 5, (N, PE)),
    )
    args = {k: jnp.asarray(v.astype(np.int32)) for k, v in shapes.items()}
    epr = jnp.arange(N, dtype=jnp.int32)
    kw = dict(W=W, P=P, V=V, PE=PE, p_budget=PE, NQ=NQ, R=R)
    ref_out = alloc_rounds_ref(jnp.int32(7), **args, epr_index=epr, **kw)
    pal_out = alloc_rounds_pallas(jnp.int32(7), *args.values(), epr,
                                  **kw)
    for a, b in zip(ref_out, pal_out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("ugal_g", [False, True])
def test_ugal_select_kernel_matches_ref(ugal_g):
    rng = np.random.default_rng(1)
    E, C = 700, 4
    unreach, big = 1 << 14, 1 << 30
    len_min = jnp.asarray(
        rng.choice([1, 2, unreach], E).astype(np.int32))
    len_val = jnp.asarray(
        rng.choice([2, 3, 4, unreach], (E, C)).astype(np.int32))
    occ_min = jnp.asarray(rng.integers(0, 1 << 20, E).astype(np.int32))
    occ_val = jnp.asarray(
        rng.integers(0, 1 << 20, (E, C)).astype(np.int32))
    a = ugal_select_ref(len_min, len_val, occ_min, occ_val,
                        ugal_g=ugal_g, unreach=unreach, big=big)
    b = ugal_select_pallas(len_min, len_val, occ_min, occ_val,
                           ugal_g=ugal_g, unreach=unreach, big=big)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_golden_outcomes_q5():
    """The packed-dtype / shift-FIFO / kernel refactor must not change
    any simulated outcome: these numbers were produced by the seed
    (PR 3) engine and must stay fixed.

    Caveat: the exact integers depend on jax.random's sampler bits
    (jax is lower-bounded, not pinned, in requirements.txt).  If this
    test fails after a jax upgrade with NO engine change, re-derive
    the goldens from the new jax rather than suspecting the engine —
    the ref==pallas equivalence tests above are the version-robust
    check."""
    tables = SimTables.build(cached_slimfly(5))
    uni = make_traffic(tables, "uniform")
    r = simulate(tables, uni, SimConfig(
        injection_rate=0.35, cycles=150, warmup=40, mode="min", seed=7))
    assert r.delivered == 10342 and r.injected == 10530
    assert round(r.avg_latency, 9) == 3.452124204
    r = simulate(tables, uni, SimConfig(
        injection_rate=0.35, cycles=150, warmup=40, mode="ugal_l", seed=7))
    assert r.delivered == 10228 and r.injected == 10530
    assert round(r.avg_latency, 9) == 5.108265425


# ------------------------------------------------------ overflow guards --
def test_packed_record_boundaries():
    """Round-trip at the field-budget edges (q=25-scale router ids, max
    hops/phase/msg, near-int32 inject cycles)."""
    dst = jnp.int32(1249)
    inter = jnp.int32(MAX_ROUTERS - 1)
    time = jnp.int32(2_000_000_000)
    pkt = pack_record(dst, inter, time, jnp.int32(HOPS_MAX), jnp.int32(1),
                      msg=jnp.int32(MAX_MSGS - 1))
    got = np.asarray(unpack_record(pkt, 6))
    assert got.tolist() == [1249, MAX_ROUTERS - 1, 2_000_000_000,
                            HOPS_MAX, 1, MAX_MSGS - 1]
    assert (np.asarray(pkt) >= 0).all()          # no sign-bit corruption


def test_hops_saturate_not_wrap():
    """hops pins at HOPS_MAX instead of carrying into the phase bit."""
    pkt = pack_record(jnp.int32(3), jnp.int32(4), jnp.int32(0),
                      jnp.int32(HOPS_MAX), jnp.int32(0),
                      msg=jnp.int32(12345))
    w2 = bump_hops_word(pkt[..., 2], jnp.int32(0))
    got = np.asarray(unpack_record(pkt.at[..., 2].set(w2), 6))
    assert got[3] == HOPS_MAX                    # saturated
    assert got[4] == 0 and got[5] == 12345       # neighbors untouched


def test_alloc_priority_fits_int32_at_paper_scale():
    """The seed's rot*R+qidx priority wrapped int32 at q=17
    (R=65314); the replacement rot/KSHIFT packing must keep every
    intermediate below 2^31 up to q=25 and closed-loop max_cycles."""
    from repro.core import slimfly_params
    max_cycle = 200_000
    for q in (17, 25):
        par = slimfly_params(q)
        PV = par["kprime"] * 4
        NQ = par["n_routers"] * PV
        R = NQ + par["n_endpoints"]
        K = PV + par["p"]
        worst_rot_arg = (R - 1) + max_cycle * 7919 + 3 * 131
        assert worst_rot_arg < 2**31, (q, worst_rot_arg)
        assert (R - 1) * KSHIFT + K < 2**31, (q, R)
        assert K < KSHIFT, (q, K)
        # and the seed formula really did overflow — the regression this
        # guards against is real, not hypothetical
        if q == 17:
            assert (R - 1) * R + (R - 1) >= 2**31


def test_q17_saturated_sim_no_wraparound():
    """Acceptance-scale run: q=17 (N=578, ~7.5k endpoints) at a
    saturating injection rate pushes queue occupancy against its caps;
    conservation must hold at every cycle prefix and all counters stay
    in range."""
    tables = SimTables.build(cached_slimfly(17))
    uni = make_traffic(tables, "uniform")
    cfg = SimConfig(injection_rate=1.0, cycles=40, warmup=0,
                    mode="ugal_l", seed=2)
    r = simulate(tables, uni, cfg)
    cum_inj = np.cumsum(r.per_cycle_injected)
    cum_dlv = np.cumsum(r.per_cycle_delivered)
    np.testing.assert_array_equal(cum_inj,
                                  cum_dlv + r.per_cycle_in_flight)
    assert (r.per_cycle_in_flight >= 0).all()
    cap = (tables.n_routers * tables.P * cfg.vcs * cfg.q_net
           + tables.n_endpoints * cfg.q_src)
    assert (r.per_cycle_in_flight <= cap).all()
    assert r.delivered > 0 and r.avg_latency > 0


# ------------------------------------------------- donation / peak memory --
def test_donated_carry_stays_donatable():
    """The scan carry is donated (jax.jit donate_argnums) and must keep
    an aliasable target: if aliasing breaks, jax emits the 'Some
    donated buffers were not usable' UserWarning again."""
    import warnings

    tables = SimTables.build(cached_slimfly(5))
    tr = make_traffic(tables, "uniform")
    cfg = SimConfig(injection_rate=0.3, cycles=30, warmup=0, mode="min",
                    seed=11)
    simulate(tables, tr, cfg)                    # compile outside the net
    with warnings.catch_warnings():
        warnings.simplefilter("error", UserWarning)
        r = simulate(tables, tr, dataclasses.replace(cfg, seed=12))
    assert r.delivered > 0


def test_steady_state_memory_bounded():
    """Steady-state re-execution of the compiled scan must not grow the
    process high-water mark by more than a loose cap (a donation or
    buffer-retention regression shows up as per-call growth on the
    order of the full queue state x cycles)."""
    from repro.bench import peak_memory_bytes

    tables = SimTables.build(cached_slimfly(7))
    tr = make_traffic(tables, "uniform")
    state = {"seed": 20}

    def call():
        cfg = SimConfig(injection_rate=0.3, cycles=60, warmup=0,
                        mode="min", seed=state["seed"])
        state["seed"] += 1
        simulate(tables, tr, cfg)

    call()                                       # compile + set the HWM
    peak, probe = peak_memory_bytes(call, cheap=True)
    assert probe in ("rss", "rss-total", "none")
    if probe == "rss":                           # the HWM moved: bound it
        assert peak < 256 * 1024 * 1024, peak


# --------------------------------------------------------- bench harness --
def test_rss_probe_never_null():
    """The cheap RSS probe (paper-scale entries) always yields a
    number on Linux — peak_mem_bytes must not be null at q=17 again."""
    from repro.bench import peak_memory_bytes, rss_hwm_bytes

    assert rss_hwm_bytes() is None or rss_hwm_bytes() > 0

    peak, probe = peak_memory_bytes(lambda: np.zeros(1 << 22), cheap=True)
    if probe != "none":                          # /proc or getrusage found
        assert peak is not None and peak > 0
        assert probe in ("rss", "rss-total")

    e = bench_callable("toy/rss", lambda: None, repeats=1,
                       measure_memory="rss")
    assert e.mem_probe in ("rss", "rss-total", "none")
    if e.mem_probe != "none":
        assert e.peak_mem_bytes is not None


def test_enable_compilation_cache_states(tmp_path, monkeypatch):
    """REPRO_CACHE_DIR knob: off when unset, cold on an empty dir,
    warm once the dir holds serialized executables."""
    import jax

    from repro.bench import enable_compilation_cache

    monkeypatch.delenv("REPRO_CACHE_DIR", raising=False)
    assert enable_compilation_cache() == ("off", None)

    cache = tmp_path / "jc"
    monkeypatch.setenv("REPRO_CACHE_DIR", str(cache))
    try:
        state, d = enable_compilation_cache()
        assert state == "cold" and d == str(cache) and cache.is_dir()
        (cache / "jit_foo-0123-cache").write_bytes(b"x")
        state, _ = enable_compilation_cache()
        assert state == "warm"
    finally:
        # don't leave the suite persisting executables into tmp_path
        jax.config.update("jax_compilation_cache_dir", None)


def test_bench_extra_metrics_roundtrip(tmp_path):
    """extra_metrics (sweep_points_per_sec & co) serialize beside the
    standard fields and are addressable by check_regression."""
    from repro.bench import BenchEntry

    e = BenchEntry(name="sweep/q0/t", wall_s=2.0, wall_mean_s=2.0,
                   compile_s=1.0, repeats=1, cycles=100,
                   meta={"lanes": 5},
                   extra_metrics={"sweep_points_per_sec": 2.5})
    path = tmp_path / "BENCH_x.json"
    write_bench(str(path), "engine_scaling", [e])
    doc = load_bench(str(path))
    ent = doc["entries"]["sweep/q0/t"]
    assert ent["sweep_points_per_sec"] == 2.5
    ok, msg = check_regression(doc, "sweep/q0/t", "sweep_points_per_sec",
                               1.0, factor=2.0, higher_is_better=True)
    assert not ok and "REGRESSION" in msg
    ok, _ = check_regression(doc, "sweep/q0/t", "sweep_points_per_sec",
                             1.5, factor=2.0, higher_is_better=True)
    assert ok


def test_bench_harness_roundtrip(tmp_path):
    calls = []

    def fn():
        calls.append(1)

    e = bench_callable("toy/q0", fn, repeats=3, cycles=1000,
                       measure_memory=True, meta={"q": 0})
    assert e.repeats == 3 and len(calls) >= 4      # warmup + repeats (+mem)
    assert e.cycles_per_sec is not None and e.cycles_per_sec > 0
    # "none" is legitimate on device-stats backends: a pure-Python fn
    # moves no device memory, and the probe refuses misleading zeros
    assert e.mem_probe in ("device", "tracemalloc", "tracemalloc-nested",
                           "none")

    path = tmp_path / "BENCH_toy.json"
    doc = write_bench(str(path), "toy", [e], extra_meta={"note": "t"})
    loaded = load_bench(str(path))
    assert loaded == doc
    ent = loaded["entries"]["toy/q0"]
    assert ent["cycles"] == 1000 and ent["meta"]["q"] == 0
    assert ent["cycles_per_sec"] == pytest.approx(e.cycles_per_sec)


def test_check_regression_gate():
    baseline = {"schema": 1, "entries": {
        "engine/q5/ugal_l": {"cycles_per_sec": 100.0}}}
    ok, _ = check_regression(baseline, "engine/q5/ugal_l",
                             "cycles_per_sec", 60.0, factor=2.0)
    assert ok                                       # within 2x
    ok, msg = check_regression(baseline, "engine/q5/ugal_l",
                               "cycles_per_sec", 40.0, factor=2.0)
    assert not ok and "REGRESSION" in msg           # > 2x slower
    ok, msg = check_regression(baseline, "engine/q99/ugal_l",
                               "cycles_per_sec", 1.0, factor=2.0)
    assert ok and "no baseline" in msg              # new entry passes
    # lower-is-better metrics flip the comparison
    base2 = {"schema": 1, "entries": {"e": {"wall_s": 1.0}}}
    ok, _ = check_regression(base2, "e", "wall_s", 3.0, factor=2.0,
                             higher_is_better=False)
    assert not ok
