"""Distributed substrate: sharding rules, checkpoints (incl. ELASTIC
restore), quantized optimizer states, EF-int8 compression, overlapped
collectives, fault monitor, data pipeline determinism.

Multi-device cases run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main test process
must keep seeing 1 device — dry-run contract)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get, reduced
from repro.launch.faults import FaultMonitor
from repro.data import SyntheticLM
from repro.models.model import init_params, param_shapes
from repro.optim.adamw import (AdamWConfig, adamw_update,
                               dequantize_blockwise, init_opt_state,
                               quantize_blockwise)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ------------------------------------------------------------- sharding --
def test_param_specs_cover_all_archs():
    code = """
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.configs import ARCHS
    from repro.dist.sharding import param_specs
    from repro.models.model import param_shapes
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    for name, cfg in ARCHS.items():
        shapes = param_shapes(cfg)
        specs = param_specs(shapes, mesh, fsdp=True)
        import dataclasses
        cfg2 = dataclasses.replace(cfg, scan_layers=True)
        specs2 = param_specs(param_shapes(cfg2), mesh, fsdp=True)
        # all specs constructible and dims divide
        def check(sh, sp):
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            for dim, entry in enumerate(sp):
                if entry is None: continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                prod = 1
                for a in axes: prod *= sizes[a]
                assert sh[dim] % prod == 0, (name, sh, sp)
        import numpy as np
        jax.tree.map(lambda s, p: check(s, p), shapes, specs,
                     is_leaf=lambda x: isinstance(x, tuple) and all(
                         isinstance(i, (int, np.integer)) for i in x))
    print("OK")
    """
    assert "OK" in run_subprocess(code)


def test_sharded_train_step_runs_on_8_devices():
    """Real (allocated) sharded train step on a 2x4 mesh: loss finite and
    matches the single-device value."""
    code = """
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.configs import get, reduced
    from repro.dist.sharding import batch_spec, param_specs, shard_params
    from repro.models.model import init_params, loss_fn
    from repro.optim.adamw import AdamWConfig, init_opt_state
    from repro.train.loop import TrainConfig, make_train_step
    from jax.sharding import NamedSharding

    cfg = reduced(get("gemma2-2b"))
    cfg = dataclasses.replace(cfg, dp_axes=("data",), tp_axis="model",
                              scan_layers=True)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = dict(tokens=jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                           0, cfg.vocab))
    ref_loss = float(loss_fn(params, batch, cfg))

    with mesh:
        sp = shard_params(params, mesh, fsdp=True)
        sb = jax.device_put(batch["tokens"],
                            NamedSharding(mesh, batch_spec(mesh)))
        opt_cfg = AdamWConfig()
        opt = init_opt_state(sp, opt_cfg)
        step = jax.jit(make_train_step(cfg, opt_cfg, TrainConfig()))
        p2, o2, metrics = step(sp, opt, dict(tokens=sb))
        loss = float(metrics["loss"])
    assert abs(loss - ref_loss) / abs(ref_loss) < 1e-3, (loss, ref_loss)
    print("OK", loss)
    """
    assert "OK" in run_subprocess(code)


# ----------------------------------------------------------- checkpoints --
def test_checkpoint_roundtrip(tmp_path):
    from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint
    cfg = reduced(get("h2o-danube-1.8b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params, AdamWConfig())
    tree = dict(p=params, o=opt)
    save_checkpoint(str(tmp_path), 42, tree)
    assert latest_step(str(tmp_path)) == 42
    restored = restore_checkpoint(str(tmp_path), 42, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Save on an 8-device (2,4) mesh, restore onto (4,2) AND (1,8):
    elastic re-sharding via global arrays."""
    code = f"""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get, reduced
    from repro.dist.sharding import param_specs, shard_params
    from repro.models.model import init_params
    from repro.ckpt import restore_checkpoint, save_checkpoint

    cfg = reduced(get("h2o-danube-1.8b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    mesh1 = jax.make_mesh((2, 4), ("data", "model"))
    sp = shard_params(params, mesh1, fsdp=True)
    save_checkpoint({str(tmp_path)!r}, 1, sp)

    for shape in [(4, 2), (1, 8)]:
        mesh2 = jax.make_mesh(shape, ("data", "model"))
        specs2 = param_specs(params, mesh2, fsdp=True)
        restored = restore_checkpoint({str(tmp_path)!r}, 1, params,
                                      mesh=mesh2, specs=specs2)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("OK")
    """
    assert "OK" in run_subprocess(code)


def test_train_resume_reproduces(tmp_path):
    """checkpoint/restart: 4 steps straight == 2 steps + resume + 2."""
    from repro.train import TrainConfig, train
    cfg = reduced(get("h2o-danube-1.8b"), n_layers=2)
    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=2, total_steps=10)
    data = SyntheticLM(cfg.vocab, 16, 4, seed=3)
    params = init_params(jax.random.PRNGKey(0), cfg)

    pA, _, _ = train(cfg, opt_cfg, TrainConfig(), data, params, 4)

    d1 = str(tmp_path / "resume")
    tc = TrainConfig(ckpt_dir=d1, ckpt_every=2)
    pB, _, _ = train(cfg, opt_cfg, tc, data, params, 2)
    pB2, _, _ = train(cfg, opt_cfg, tc, data, params, 4)  # resumes at 2
    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)


# ------------------------------------------------------ quantized states --
def test_blockwise_quant_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000) * 0.01, jnp.float32)
    q, s, shp = quantize_blockwise(x)
    y = dequantize_blockwise(q, s, shp)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=2e-4)


def test_quantized_adamw_tracks_fp32():
    cfg = reduced(get("h2o-danube-1.8b"), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 1e-3, params)
    for quant in [False, True]:
        ocfg = AdamWConfig(quantized_state=quant, lr_peak=1e-3,
                           warmup_steps=1)
        st = init_opt_state(params, ocfg)
        p1, st, _ = adamw_update(params, grads, st, ocfg)
        if quant:
            p_q = p1
        else:
            p_f = p1
    err = max(float(jnp.abs(a - b).max())
              for a, b in zip(jax.tree.leaves(p_q), jax.tree.leaves(p_f)))
    assert err < 1e-4


# ----------------------------------------------------------- compression --
def test_compressed_psum_approximates_mean():
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.optim.compression import compressed_psum

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    g = rng.standard_normal((8, 1024)).astype(np.float32)

    def body(x):
        out, err = compressed_psum(x[0], "data")
        return out, err[None]
    f = jax.shard_map(body, mesh=mesh, in_specs=P("data", None),
                      out_specs=(P(), P("data", None)), check_vma=False)
    out, err = f(g)
    expect = g.mean(axis=0)
    rel = np.abs(np.asarray(out) - expect).max() / np.abs(expect).max()
    assert rel < 0.05, rel
    # error feedback: residual + transmitted == original contribution
    print("OK", rel)
    """
    assert "OK" in run_subprocess(code)


def test_collective_matmul_overlap_hlo():
    """The ring collective-matmul lowers to while{dot, collective-permute}
    (overlap), not {all-gather, dot}."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.dist.collectives import collective_matmul_ag

    mesh = jax.make_mesh((8,), ("model",))
    x = jnp.ones((64, 32), jnp.float32)
    w = jnp.ones((32, 16), jnp.float32)
    f = jax.shard_map(lambda xs, ws: collective_matmul_ag(xs, ws, "model"),
                      mesh=mesh, in_specs=(P("model", None), P(None, None)),
                      out_specs=P(None, None), check_vma=False)
    with mesh:
        lowered = jax.jit(f).lower(x, w)
        compiled = lowered.compile()
    text = compiled.as_text()
    assert "collective-permute" in text
    out = jax.jit(f)(x, w)
    np.testing.assert_allclose(np.asarray(out)[:8],
                               np.asarray(x @ w)[:8], rtol=1e-6)
    # result must equal all_gather(x) @ w = x @ w here (x replicated rows)
    print("OK")
    """
    assert "OK" in run_subprocess(code)


def test_ring_all_reduce_correct():
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.collectives import ring_all_reduce

    mesh = jax.make_mesh((8,), ("d",))
    rng = np.random.default_rng(1)
    g = rng.standard_normal((8, 37)).astype(np.float32)
    f = jax.shard_map(lambda x: ring_all_reduce(x[0], "d"),
                      mesh=mesh, in_specs=P("d", None), out_specs=P(),
                      check_vma=False)
    out = f(g)
    np.testing.assert_allclose(np.asarray(out), g.sum(0), rtol=1e-5)
    print("OK")
    """
    assert "OK" in run_subprocess(code)


def test_ring_reduce_scatter_and_all_gather_index_aligned():
    """Device d's reduce-scatter output is chunk d, and ring_all_gather
    places shard d at index d — composing them reassembles the plain
    all-reduce with no block permutation."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.collectives import ring_all_gather, ring_reduce_scatter

    mesh = jax.make_mesh((4,), ("d",))
    rng = np.random.default_rng(2)
    g = rng.standard_normal((4, 8, 3)).astype(np.float32)

    f = jax.shard_map(lambda x: ring_reduce_scatter(x[0], "d"),
                      mesh=mesh, in_specs=P("d", None, None),
                      out_specs=P("d", None), check_vma=False)
    out = np.asarray(f(g))                       # [8, 3] re-concatenated
    np.testing.assert_allclose(out, g.sum(0), rtol=1e-5)

    f2 = jax.shard_map(
        lambda x: ring_all_gather(ring_reduce_scatter(x[0], "d"), "d")
                  .reshape(8, 3),
        mesh=mesh, in_specs=P("d", None, None), out_specs=P(),
        check_vma=False)
    np.testing.assert_allclose(np.asarray(f2(g)), g.sum(0), rtol=1e-5)
    print("OK")
    """
    assert "OK" in run_subprocess(code, devices=4)


# -------------------------------------------------------- fault tolerance --
def test_fault_monitor_straggler_detection():
    m = FaultMonitor(straggler_factor=3.0)
    t = 0.0
    for step in range(10):
        m.heartbeat(step, now=t)
        t += 1.0
    assert not m.is_straggling
    m.heartbeat(10, now=t + 10.0)     # 10x the EMA step time
    assert m.is_straggling
    assert m.straggler_events[0]["step"] == 10


def test_preemption_checkpoints_and_exits(tmp_path):
    from repro.ckpt import latest_step
    from repro.train import TrainConfig, train
    cfg = reduced(get("h2o-danube-1.8b"), n_layers=2)
    data = SyntheticLM(cfg.vocab, 16, 4, seed=3)
    params = init_params(jax.random.PRNGKey(0), cfg)
    monitor = FaultMonitor()
    monitor.inject_preemption()
    tc = TrainConfig(ckpt_dir=str(tmp_path))
    train(cfg, AdamWConfig(), tc, data, params, 50, monitor=monitor)
    # exited after the first step with a checkpoint on disk
    assert latest_step(str(tmp_path)) == 1


# ---------------------------------------------------------- data pipeline --
def test_data_pipeline_deterministic_and_sharded():
    a = SyntheticLM(1000, 32, 8, seed=5).batch_at(17)
    b = SyntheticLM(1000, 32, 8, seed=5).batch_at(17)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    # shards partition the stream deterministically and differ
    s0 = SyntheticLM(1000, 32, 8, seed=5, n_shards=2, shard=0).batch_at(17)
    s1 = SyntheticLM(1000, 32, 8, seed=5, n_shards=2, shard=1).batch_at(17)
    assert s0["tokens"].shape == (4, 32)
    assert not np.array_equal(np.asarray(s0["tokens"]),
                              np.asarray(s1["tokens"]))


def test_prefetcher_overlaps():
    from repro.data import Prefetcher
    src = SyntheticLM(100, 8, 2, seed=1)
    pf = Prefetcher(src, start_step=3)
    step, batch = pf.next()
    assert step == 3
    np.testing.assert_array_equal(np.asarray(batch["tokens"]),
                                  np.asarray(src.batch_at(3)["tokens"]))
    pf.close()
