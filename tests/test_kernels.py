"""Per-kernel allclose tests: Pallas (interpret mode on CPU) vs ref.py
oracles, swept over shapes and dtypes, plus semiring property tests."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

# hypothesis when installed, deterministic fallback otherwise
from _hypothesis_compat import given, settings, st

from repro.core import build_slimfly
from repro.core.topologies import build_dragonfly, build_torus
from repro.kernels import apsp, decode_attention, minplus, seed_distance
from repro.kernels.ref import decode_attention_ref, minplus_ref


# ---------------------------------------------------------------- minplus --
@pytest.mark.parametrize("shape", [
    (1, 8, 8, 8),        # tiny
    (1, 128, 128, 128),  # exactly one block
    (2, 100, 70, 130),   # ragged, batched
    (1, 257, 129, 63),   # off-by-one over block boundaries
    (3, 16, 300, 16),    # skinny with large K
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_minplus_matches_ref(shape, dtype):
    b, m, k, n = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    a = jnp.asarray(rng.uniform(0, 10, (b, m, k)), dtype=dtype)
    bb = jnp.asarray(rng.uniform(0, 10, (b, k, n)), dtype=dtype)
    out = minplus(a, bb)
    exp = minplus_ref(a, bb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-6)


def test_minplus_unbatched_2d():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(0, 5, (50, 60)), dtype=jnp.float32)
    b = jnp.asarray(rng.uniform(0, 5, (60, 40)), dtype=jnp.float32)
    out = minplus(a, b)
    assert out.shape == (50, 40)
    np.testing.assert_allclose(np.asarray(out), np.asarray(minplus_ref(a, b)),
                               rtol=1e-6)


def test_minplus_identity():
    """The (min,+) identity matrix (0 diag / +inf off-diag) must act as I."""
    n = 37
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.uniform(0, 9, (n, n)), dtype=jnp.float32)
    ident = seed_distance(jnp.zeros((n, n), dtype=bool))
    np.testing.assert_allclose(np.asarray(minplus(a, ident)), np.asarray(a),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(minplus(ident, a)), np.asarray(a),
                               rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(2, 24), k=st.integers(2, 24), n=st.integers(2, 24),
    j=st.integers(2, 24), seed=st.integers(0, 2**16),
)
def test_minplus_associative(m, k, n, j, seed):
    """(A*B)*C == A*(B*C) over the (min,+) semiring (property test)."""
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.integers(0, 50, (m, k)), dtype=jnp.float32)
    B = jnp.asarray(rng.integers(0, 50, (k, n)), dtype=jnp.float32)
    C = jnp.asarray(rng.integers(0, 50, (n, j)), dtype=jnp.float32)
    left = minplus(minplus(A, B), C)
    right = minplus(A, minplus(B, C))
    np.testing.assert_allclose(np.asarray(left), np.asarray(right), rtol=1e-6)


# ------------------------------------------------------------------- apsp --
@pytest.mark.parametrize("make", [
    lambda: build_slimfly(5),
    lambda: build_slimfly(7),
    lambda: build_dragonfly(h=2),
    lambda: build_torus(4, 3),
])
def test_apsp_matches_bfs_oracle(make):
    topo = make()
    d_kernel = np.asarray(apsp(topo.adj, max_diameter=topo.n_routers))
    d_oracle = topo.distance_matrix()
    finite = np.isfinite(d_oracle)
    assert finite.all()  # all comparison graphs are connected
    np.testing.assert_array_equal(d_kernel[finite], d_oracle[finite])


def test_apsp_batched_with_disconnection():
    """Batched APSP over perturbed adjacencies; removed cut edges must show
    up as unreachable (>= 1e37)."""
    topo = build_torus(4, 2)  # ring-ish, easy to cut
    adj = np.asarray(topo.adj)
    batch = np.stack([adj, adj])
    # cut all edges of node 0 in sample 1
    batch[1, 0, :] = False
    batch[1, :, 0] = False
    d = np.asarray(apsp(jnp.asarray(batch), max_diameter=topo.n_routers))
    assert np.isfinite(d[0]).all() or (d[0] < 1e37).all()
    assert (d[1, 0, 1:] > 1e37).all()  # node 0 unreachable
    d0 = topo.distance_matrix()
    np.testing.assert_array_equal(d[0], d0)


# -------------------------------------------------------- decode attention --
@pytest.mark.parametrize("cfg", [
    dict(B=1, Hkv=1, G=1, d=32, S=64),      # minimal
    dict(B=2, Hkv=4, G=7, d=64, S=300),     # ragged everything
    dict(B=1, Hkv=2, G=8, d=128, S=1024),   # aligned
    dict(B=3, Hkv=1, G=16, d=80, S=129),    # d and S need padding
])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
def test_decode_attention_matches_ref(cfg, dtype, tol):
    B, Hkv, G, d, S = cfg["B"], cfg["Hkv"], cfg["G"], cfg["d"], cfg["S"]
    rng = np.random.default_rng(B * 1000 + S)
    q = jnp.asarray(rng.normal(size=(B, Hkv, G, d)), dtype=dtype)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, d)), dtype=dtype)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, d)), dtype=dtype)
    length = jnp.asarray(rng.integers(1, S + 1, (B,)), dtype=jnp.int32)
    out = decode_attention(q, k, v, length, bs=128, use_pallas=True)
    exp = decode_attention_ref(q, k, v, length=length)
    np.testing.assert_allclose(
        np.asarray(out, dtype=np.float32), np.asarray(exp, dtype=np.float32),
        rtol=tol, atol=tol)


def test_decode_attention_full_length_default():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(1, 2, 4, 64)), dtype=jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 200, 64)), dtype=jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 200, 64)), dtype=jnp.float32)
    out = decode_attention(q, k, v, bs=128, use_pallas=True)
    exp = decode_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_invariance_to_padding():
    """Extending the cache with garbage beyond `length` must not change
    the output (the mask is doing its job)."""
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(1, 1, 4, 32)), dtype=jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 100, 32)), dtype=jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 1, 100, 32)), dtype=jnp.float32)
    length = jnp.asarray([60], dtype=jnp.int32)
    out1 = decode_attention(q, k, v, length, bs=64, use_pallas=True)
    k2 = k.at[:, :, 60:].set(1e3)
    v2 = v.at[:, :, 60:].set(-1e3)
    out2 = decode_attention(q, k2, v2, length, bs=64, use_pallas=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)
