"""Routing (§IV): minimality, VC assignment, deadlock-freedom (CDG
acyclicity), Valiant paths, channel load (§II-B2)."""

import numpy as np
import pytest

# hypothesis when installed, deterministic fallback otherwise
from _hypothesis_compat import given, settings, st

from repro.core import build_slimfly
from repro.core.routing import (
    analytic_channel_load,
    assign_vcs,
    build_routing,
    channel_load_uniform,
    is_deadlock_free,
    valiant_path,
)
from repro.core.topologies import build_dragonfly, build_fattree3


@pytest.fixture(scope="module")
def sf5():
    topo = build_slimfly(5)
    return topo, build_routing(topo)


def test_min_paths_are_minimal(sf5):
    topo, rt = sf5
    n = topo.n_routers
    for s in range(n):
        for d in range(n):
            path = rt.min_path(s, d)
            assert len(path) - 1 == rt.dist[s, d]
            for u, v in zip(path[:-1], path[1:]):
                assert topo.adj[u, v]


def test_min_routing_deadlock_free_2vcs(sf5):
    """§IV-D: hop-indexed VCs with D=2 => at most VC0, VC1, CDG acyclic."""
    topo, rt = sf5
    n = topo.n_routers
    paths = [rt.min_path(s, d) for s in range(n) for d in range(n) if s != d]
    assert max(max(assign_vcs(p), default=0) for p in paths) <= 1
    assert is_deadlock_free(paths, n)


def test_valiant_deadlock_free_4vcs(sf5):
    topo, rt = sf5
    n = topo.n_routers
    rng = np.random.default_rng(0)
    paths = []
    for _ in range(500):
        s, d, r = rng.integers(0, n, 3)
        paths.append(valiant_path(rt, int(s), int(d), int(r)))
    assert max(len(p) - 1 for p in paths) <= 4    # §IV-B
    assert max(max(assign_vcs(p), default=0) for p in paths) <= 3
    assert is_deadlock_free(paths, n)


def test_cyclic_path_set_detected():
    """Sanity: single-VC routing around a ring IS cyclic in the CDG."""
    ring = [[0, 1, 2], [1, 2, 3], [2, 3, 0], [3, 0, 1]]

    # force all hops onto VC0 by flattening to 1-hop chained deps
    from repro.core.routing import channel_dependency_graph
    import repro.core.routing as routing_mod

    orig = routing_mod.assign_vcs
    routing_mod.assign_vcs = lambda path: [0] * (len(path) - 1)
    try:
        assert not is_deadlock_free(ring, 4)
    finally:
        routing_mod.assign_vcs = orig


def test_channel_load_matches_analytic(sf5):
    """§II-B2 validation: empirical mean channel load equals the closed
    form l = (2 N_r - k' - 2) p^2 / k'."""
    topo, rt = sf5
    avg, mx = channel_load_uniform(rt)
    expected = analytic_channel_load(topo.network_radix, topo.n_routers,
                                     topo.p)
    assert abs(avg - expected) / expected < 1e-9
    # SF MMS is edge-transitive-ish: max close to mean (balanced design)
    assert mx <= expected * 1.5


def test_balanced_injection(sf5):
    """Balanced network: per-endpoint injection (N routes) ~ channel load."""
    topo, rt = sf5
    avg, _ = channel_load_uniform(rt)
    # endpoint uplink carries ~ N = p * N_r routes; channels carry ~l
    n_dest = topo.p * topo.n_routers
    assert avg <= n_dest * 1.1   # balanced: l <= injection capacity


@settings(max_examples=15, deadline=None)
@given(q=st.sampled_from([5, 7, 9]), seed=st.integers(0, 10_000))
def test_valiant_path_valid(q, seed):
    topo = build_slimfly(q)
    rt = build_routing(topo, use_pallas=False)
    rng = np.random.default_rng(seed)
    s, d, r = (int(x) for x in rng.integers(0, topo.n_routers, 3))
    p = valiant_path(rt, s, d, r)
    assert p[0] == s and p[-1] == d
    assert r in p
    for u, v in zip(p[:-1], p[1:]):
        assert topo.adj[u, v]


@pytest.mark.parametrize("q", [7, 11, 17])
def test_channel_load_matches_analytic_paper_scales(q):
    """§II-B2 at the simulator target sizes (DESIGN.md §9): empirical
    mean channel load equals l = (2 N_r - k' - 2) p^2 / k' at q = 7,
    11 and 17 — the loads the scaled engine is validated against."""
    from conftest import cached_slimfly

    topo = cached_slimfly(q)
    rt = build_routing(topo, use_pallas=False)
    avg, mx = channel_load_uniform(rt)
    expected = analytic_channel_load(topo.network_radix, topo.n_routers,
                                     topo.p)
    assert abs(avg - expected) / expected < 1e-9
    assert mx <= expected * 1.5


def test_routing_on_other_topologies():
    for topo in [build_dragonfly(h=2), build_fattree3(p=4)]:
        rt = build_routing(topo, use_pallas=False)
        n = topo.n_routers
        rng = np.random.default_rng(1)
        paths = []
        for _ in range(300):
            s, d = rng.integers(0, n, 2)
            if s != d:
                paths.append(rt.min_path(int(s), int(d)))
        assert is_deadlock_free(paths, n)
