"""Per-architecture smoke tests (deliverable f): reduced same-family
configs run one forward/train step on CPU asserting shapes + no NaNs,
plus prefill->decode consistency against the teacher-forced forward."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get, reduced
from repro.models.model import (decode_step, forward, init_cache,
                                init_params, loss_fn, param_count,
                                param_shapes, prefill)

ALL_ARCHS = sorted(ARCHS)
RNG = jax.random.PRNGKey(0)


def _make_batch(r, B=2, S=24):
    batch = dict(tokens=jax.random.randint(RNG, (B, S), 0, r.vocab))
    if r.frontend == "vision_stub":
        batch["patches"] = jax.random.normal(
            RNG, (B, r.n_frontend_tokens, r.d_model)) * 0.02
    if r.frontend == "audio_stub":
        batch["frames"] = jax.random.normal(
            RNG, (B, r.n_frontend_tokens, r.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_forward_and_train_step(name):
    r = reduced(get(name))
    params = init_params(RNG, r)
    batch = _make_batch(r)
    logits = forward(params, batch, r)
    S_total = batch["tokens"].shape[1] + (r.n_frontend_tokens
                                          if r.frontend == "vision_stub"
                                          else 0)
    assert logits.shape == (2, S_total, r.vocab)
    assert bool(jnp.isfinite(logits).all())
    loss, grads = jax.value_and_grad(loss_fn)(params, batch, r)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(g * g)) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_matches_forward(name):
    r = reduced(get(name))
    if r.n_experts:   # dropless capacity for numerical comparability
        r = dataclasses.replace(r, capacity_factor=float(r.n_experts))
    params = init_params(RNG, r)
    B, S = 2, 24
    batch = _make_batch(r, B, S)
    toks = batch["tokens"]
    full = forward(params, batch, r)
    cache = init_cache(r, B, max_len=64, dtype=jnp.float32)
    _, cache = prefill(params, dict(batch, tokens=toks[:, : S - 1]), r, cache)
    lg, cache = decode_step(params, toks[:, S - 1:], r, cache)
    a = np.asarray(full[:, -1], np.float32)
    b = np.asarray(lg[:, 0], np.float32)
    err = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert err < 2e-2, f"{name}: decode diverges from forward ({err:.2e})"


@pytest.mark.parametrize("name,lo,hi", [
    ("gemma3-4b", 3.3, 4.5), ("h2o-danube-1.8b", 1.5, 2.1),
    ("gemma2-2b", 2.2, 3.0), ("yi-34b", 30.0, 38.0),
    ("llama4-maverick-400b-a17b", 360.0, 440.0),
    ("mixtral-8x22b", 125.0, 155.0), ("zamba2-7b", 6.0, 8.0),
    ("xlstm-1.3b", 1.0, 1.6), ("phi-3-vision-4.2b", 3.3, 4.4),
    ("whisper-small", 0.2, 0.4),
])
def test_full_config_param_counts(name, lo, hi):
    """The FULL configs match their nameplates (checked via shapes only —
    nothing is allocated)."""
    shapes = param_shapes(get(name))
    n = sum(int(np.prod(s)) for s in
            jax.tree.leaves(shapes, is_leaf=lambda x: isinstance(x, tuple)))
    assert lo <= n / 1e9 <= hi, f"{name}: {n/1e9:.2f}B"


def test_layer_patterns():
    """Architecture-defining layer patterns."""
    g3 = get("gemma3-4b").layer_kinds()          # 5 local : 1 global
    windows = [s["window"] for s in g3[:12]]
    assert windows == [1024] * 5 + [None] + [1024] * 5 + [None]

    g2 = get("gemma2-2b").layer_kinds()          # alternating
    assert [s["window"] for s in g2[:4]] == [4096, None, 4096, None]

    l4 = get("llama4-maverick-400b-a17b").layer_kinds()
    assert [s["ffn"] for s in l4[:4]] == ["dense", "moe", "dense", "moe"]

    mx = get("mixtral-8x22b").layer_kinds()
    assert all(s["ffn"] == "moe" for s in mx)

    zb = get("zamba2-7b").layer_kinds()
    assert sum(s.get("shared_attn", False) for s in zb) == 81 // 6
    assert all(s["kind"] == "mamba" for s in zb)

    xl = get("xlstm-1.3b").layer_kinds()
    assert [s["kind"] for s in xl[:8]] == ["mlstm"] * 7 + ["slstm"]


def test_shape_suite_defined():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    assert SHAPES["long_500k"].seq_len == 524_288
    assert SHAPES["decode_32k"].kind == "decode"


def test_long_context_support_flags():
    """DESIGN.md §4: long_500k runs for SSM/hybrid/windowed archs only."""
    runs = {n for n, c in ARCHS.items() if c.supports_long}
    assert runs == {"gemma3-4b", "h2o-danube-1.8b", "gemma2-2b",
                    "mixtral-8x22b", "zamba2-7b", "xlstm-1.3b"}


def test_moe_capacity_drops_are_bounded():
    """With cf=1.25 and uniform-ish routing, most tokens survive dispatch;
    the layer must stay finite and contribute nonzero output."""
    r = reduced(get("mixtral-8x22b"))
    params = init_params(RNG, r)
    batch = _make_batch(r, 2, 32)
    logits = forward(params, batch, r)
    assert bool(jnp.isfinite(logits).all())


def test_window_cache_smaller_than_global():
    """SWA layers must allocate ring caches of window size, not max_len —
    the long_500k memory story depends on it."""
    r = reduced(get("gemma3-4b"))
    cache = init_cache(r, batch_size=1, max_len=256)
    sizes = [c["kv"]["k"].shape[2] for c in cache["layers"]]
    assert min(sizes) == 16           # reduced window
    assert max(sizes) == 256          # global layer
