"""Serving engine: continuous-batching slot bookkeeping + consistency
with the single-sequence prefill/decode path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get, reduced
from repro.models.model import decode_step, init_cache, init_params, prefill
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get("h2o-danube-1.8b"), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_engine_serves_all_requests(setup):
    cfg, params = setup
    engine = ServingEngine(params, cfg, batch_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 5 + i),
                    max_new_tokens=4 + i % 3) for i in range(5)]
    done = engine.run(reqs)
    assert len(done) == 5
    for r in done:
        assert len(r.out_tokens) == r.max_new_tokens


def test_engine_matches_single_sequence_path(setup):
    """Greedy tokens from the batched engine == plain prefill+decode."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 7)
    n_new = 5

    # single-sequence reference
    cache = init_cache(cfg, 1, 64, dtype=jnp.float32)
    logits, cache = prefill(params, dict(
        tokens=jnp.asarray(prompt[None], jnp.int32)), cfg, cache)
    ref = [int(jnp.argmax(logits[0, -1]))]
    tok = jnp.asarray([[ref[-1]]], jnp.int32)
    for _ in range(n_new - 1):
        logits, cache = decode_step(params, tok, cfg, cache)
        ref.append(int(jnp.argmax(logits[0, -1])))
        tok = jnp.asarray([[ref[-1]]], jnp.int32)

    engine = ServingEngine(params, cfg, batch_slots=2, max_len=64)
    done = engine.run([Request(rid=0, prompt=prompt, max_new_tokens=n_new)])
    assert done[0].out_tokens == ref


def test_enc_dec_rejected(setup):
    cfg = reduced(get("whisper-small"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(NotImplementedError):
        ServingEngine(params, cfg)
