"""Loop-aware HLO analysis (utils/hlo.py): the roofline's measurement
tool must count while-loop bodies by trip count and dots by contraction."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_flops_count_loop_trips():
    """A scan of 7 matmuls must count ~7x one matmul's FLOPs."""
    code = """
    import jax, jax.numpy as jnp
    from repro.utils.hlo import analyze_hlo

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), ()
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out.sum()

    x = jnp.ones((64, 256), jnp.float32)
    w = jnp.ones((256, 256), jnp.float32)
    t = jax.jit(f).lower(x, w).compile().as_text()
    a = analyze_hlo(t)
    per_mm = 2 * 64 * 256 * 256
    ratio = a["flops"] / (7 * per_mm)
    assert 0.9 < ratio < 1.3, ratio
    print("OK", ratio)
    """
    assert "OK" in _run(code)


def test_collective_bytes_sharded_matmul():
    """Row-sharded matmul -> one all-reduce of the result per step,
    counted at bf16 width (CPU promotes to f32)."""
    code = """
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.utils.hlo import analyze_hlo

    mesh = jax.make_mesh((8,), ("m",))
    x = jax.ShapeDtypeStruct((16, 512), jnp.bfloat16,
                             sharding=NamedSharding(mesh, P(None, "m")))
    w = jax.ShapeDtypeStruct((512, 128), jnp.bfloat16,
                             sharding=NamedSharding(mesh, P("m", None)))
    def f(x, w):
        return jnp.square((x @ w).astype(jnp.float32)).sum()
    with mesh:
        t = jax.jit(f).lower(x, w).compile().as_text()
    a = analyze_hlo(t)
    # result [16,128]: bf16 width = 4096 B (f32 would be 8192)
    ar = a["collective"]["all-reduce"]
    assert 2048 <= ar <= 3 * 4096, ar
    print("OK", ar)
    """
    assert "OK" in _run(code)


def test_dot_flops_formula():
    code = """
    import jax, jax.numpy as jnp
    from repro.utils.hlo import analyze_hlo
    f = lambda a, b: a @ b
    a = jnp.ones((37, 111), jnp.float32)
    b = jnp.ones((111, 53), jnp.float32)
    t = jax.jit(f).lower(a, b).compile().as_text()
    flops = analyze_hlo(t)["flops"]
    assert flops == 2 * 37 * 111 * 53, flops
    print("OK")
    """
    assert "OK" in _run(code)
