"""In-scan telemetry (DESIGN.md §12): telemetry-off bit-exactness vs
the golden-pinned configs, counters-ON core-result invariance (data-only
contract), counter conservation on healthy and degraded fabrics,
per-lane sweep counters, trace ring semantics, sampling determinism,
the export layer's JSON, and the `SimResult.saturated` q_src fix."""

import dataclasses
import json

import numpy as np
import pytest

from conftest import cached_slimfly
from repro.core.resiliency import failure_edge_sample
from repro.sim import (SimConfig, SimTables, TelemetryConfig, make_traffic,
                       simulate, sweep_simulate)
from repro.sim.engine import SimResult
from repro.sim.telemetry import export, sampled_fids
from repro.sim.telemetry.trace import KIND_EJECT, KIND_HOP, KIND_INJECT
from repro.sim.workloads import (WorkloadSimConfig, ring_all_reduce,
                                 run_workload)

_FULL_TRACE = TelemetryConfig(counters=True, trace=True,
                              trace_sample_shift=0, trace_capacity=1 << 14)


@pytest.fixture(scope="module")
def sf5_tables():
    return SimTables.build(cached_slimfly(5))


def _conserve(r):
    """The drained-run conservation identities (counters.py docstring).
    `r` is a completed WorkloadResult with counters on."""
    cs = r.telemetry.counters
    chan, ej, grants = (int(cs.chan_flits.sum()), int(cs.ej_count.sum()),
                        int(cs.alloc_grant.sum()))
    assert ej == r.flits_delivered
    assert chan == int(cs.ej_hops_sum.sum())
    assert grants == chan + ej
    # every delivered flit was injected exactly once and made a
    # MIN-or-VAL route decision at injection
    assert int(cs.route_min.sum() + cs.route_val.sum()) == r.flits_delivered


# ---------------------------------------------------------------------------
# telemetry OFF: bit-exact vs the pinned goldens (PR 4 / PR 6 values)
# ---------------------------------------------------------------------------

def test_open_loop_golden_bitexact_telemetry_default(sf5_tables):
    """Default TelemetryConfig() must reproduce the PR 4 goldens
    (test_engine_scaling.test_golden_outcomes_q5) exactly: the off-path
    carry gains zero pytree leaves, so the jaxpr is unchanged."""
    uni = make_traffic(sf5_tables, "uniform")
    cfg = SimConfig(injection_rate=0.35, cycles=150, warmup=40,
                    mode="min", seed=7, telemetry=TelemetryConfig())
    r = simulate(sf5_tables, uni, cfg)
    assert r.telemetry is None
    assert r.delivered == 10342 and r.injected == 10530
    assert round(r.avg_latency, 9) == 3.452124204


def test_closed_loop_golden_bitexact_telemetry_on(sf5_tables):
    """The PR 6 golden closed-loop run keeps its exact outcome even
    with counters AND tracing enabled — telemetry is data-only: no RNG
    consumed, no engine value reads a telemetry value."""
    wl = ring_all_reduce(12, 5)
    base = dict(mode="ugal_l", placement="spread", chunk=96, seed=3)
    r = run_workload(sf5_tables, wl, WorkloadSimConfig(**base))
    t = run_workload(sf5_tables, wl,
                     WorkloadSimConfig(telemetry=_FULL_TRACE, **base))
    assert r.telemetry is None and t.telemetry is not None
    for got in (r, t):
        assert got.completed and got.makespan == 182.0
        assert got.flits_delivered == 1320
        assert int(got.msg_done.sum()) == 24615
        assert int(got.msg_start.sum()) == 22478
    np.testing.assert_array_equal(r.msg_done, t.msg_done)
    np.testing.assert_array_equal(r.msg_start, t.msg_start)
    np.testing.assert_array_equal(r.per_cycle_delivered,
                                  t.per_cycle_delivered)


def test_open_loop_counters_core_results_identical(sf5_tables):
    """Open loop: enabling telemetry never perturbs the simulated
    outcome — every core field is bit-identical off vs on."""
    uni = make_traffic(sf5_tables, "uniform")
    cfg = SimConfig(injection_rate=0.3, cycles=80, warmup=20,
                    mode="ugal_l", seed=11)
    off = simulate(sf5_tables, uni, cfg)
    on = simulate(sf5_tables, uni, dataclasses.replace(
        cfg, telemetry=_FULL_TRACE))
    assert (off.delivered, off.injected, off.dropped_at_source) == \
           (on.delivered, on.injected, on.dropped_at_source)
    assert off.avg_latency == on.avg_latency
    assert off.src_occupancy == on.src_occupancy
    np.testing.assert_array_equal(off.per_cycle_delivered,
                                  on.per_cycle_delivered)
    np.testing.assert_array_equal(off.per_cycle_in_flight,
                                  on.per_cycle_in_flight)


# ---------------------------------------------------------------------------
# counter conservation: q in {5, 7}, healthy and 10%-failed
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q,failed", [(5, False), (5, True),
                                      (7, False), (7, True)])
def test_counter_conservation(q, failed):
    """On a drained closed-loop run: channel forwards == hops taken,
    ejections == flits delivered, grants == forwards + ejections, and
    route decisions == flits injected — on healthy AND degraded
    fabrics (failures reroute traffic but can't break accounting)."""
    topo = cached_slimfly(q)
    fe = (failure_edge_sample(topo, 0.10, np.random.default_rng(q))
          if failed else None)
    tables = SimTables.build(topo, failed_edges=fe)
    r = run_workload(
        tables, ring_all_reduce(8, 4),
        WorkloadSimConfig(mode="ugal_l", placement="spread", chunk=64,
                          seed=2, telemetry=TelemetryConfig(counters=True)))
    assert r.completed
    _conserve(r)
    cs = r.telemetry.counters
    # per-channel forwards can't exceed 1 flit/cycle; dead channels
    # (failed or absent) forward nothing
    assert cs.chan_flits.max() <= cs.cycles
    nbr = np.asarray(tables.nbr)
    assert cs.chan_flits[nbr < 0].sum() == 0


def test_route_counters_min_mode(sf5_tables):
    """mode=min never takes a VAL path, and every injection is
    counted: route_min == flits delivered on a drained run."""
    r = run_workload(
        sf5_tables, ring_all_reduce(8, 4),
        WorkloadSimConfig(mode="min", placement="linear", chunk=64,
                          telemetry=TelemetryConfig(counters=True)))
    assert r.completed
    cs = r.telemetry.counters
    assert int(cs.route_val.sum()) == 0
    assert int(cs.route_min.sum()) == r.flits_delivered


# ---------------------------------------------------------------------------
# lane-batched sweeps report per-lane counters (DESIGN.md §10)
# ---------------------------------------------------------------------------

def test_sweep_lane_counters_match_sequential(sf5_tables):
    tr = make_traffic(sf5_tables, "uniform")
    cfg = SimConfig(cycles=60, warmup=15, mode="ugal_l",
                    telemetry=TelemetryConfig(counters=True))
    rates, seeds = [0.15, 0.45], [3, 5]
    swept = sweep_simulate(sf5_tables, tr, cfg, rates=rates, seeds=seeds)
    for rate, seed, got in zip(rates, seeds, swept):
        want = simulate(sf5_tables, tr, dataclasses.replace(
            cfg, injection_rate=rate, seed=seed))
        assert got.delivered == want.delivered
        a, b = got.telemetry.counters, want.telemetry.counters
        np.testing.assert_array_equal(a.chan_flits, b.chan_flits)
        np.testing.assert_array_equal(a.alloc_grant, b.alloc_grant)
        np.testing.assert_array_equal(a.alloc_deny, b.alloc_deny)
        np.testing.assert_array_equal(a.ej_lat_sum, b.ej_lat_sum)
        np.testing.assert_array_equal(a.occ_max, b.occ_max)


# ---------------------------------------------------------------------------
# trace: event/span well-formedness, ring wrap, sampling
# ---------------------------------------------------------------------------

def _traced_run(sf5_tables, **tel_kw):
    tc = TelemetryConfig(counters=True, trace=True, **tel_kw)
    return run_workload(
        sf5_tables, ring_all_reduce(12, 5),
        WorkloadSimConfig(mode="ugal_l", placement="spread", chunk=96,
                          seed=3, telemetry=tc))


def test_trace_full_sample_spans(sf5_tables):
    """shift=0 traces everything: event counts match the counters
    exactly and every span is complete (inject + hops + eject)."""
    r = _traced_run(sf5_tables, trace_sample_shift=0,
                    trace_capacity=1 << 14)
    snap = r.telemetry
    assert snap.events_dropped == 0
    kinds = snap.events["kind"]
    n_inj = int((kinds == KIND_INJECT).sum())
    n_hop = int((kinds == KIND_HOP).sum())
    n_ej = int((kinds == KIND_EJECT).sum())
    cs = snap.counters
    assert n_inj == n_ej == r.flits_delivered
    assert n_hop == int(cs.chan_flits.sum())
    spans = snap.spans()
    assert len(spans) == r.flits_delivered
    for sp in spans:
        assert sp["start"] is not None and sp["end"] is not None
        assert sp["end"] >= sp["start"]
        assert sp["n_hops"] == len(sp["hops"])
        # hop cycles sit inside the span and are strictly ordered
        cycles = [c for c, _, _ in sp["hops"]]
        assert cycles == sorted(cycles)
        assert all(sp["start"] <= c <= sp["end"] for c in cycles)


def test_trace_ring_wrap(sf5_tables):
    """A tiny ring wraps: only the newest `capacity` events survive, in
    chronological order, and span decode tolerates the missing heads."""
    r = _traced_run(sf5_tables, trace_sample_shift=0, trace_capacity=64)
    snap = r.telemetry
    assert len(snap.events) <= 64
    c = snap.events["cycle"]
    assert (np.diff(c.astype(np.int64)) >= 0).all()
    # the survivors are the newest events of the run
    assert c[-1] == snap.events["cycle"].max()
    spans = snap.spans()            # partial spans decode, no crash
    assert spans and all(sp["end"] is not None or sp["hops"] or
                         sp["start"] is not None for sp in spans)


def test_trace_sampling_deterministic(sf5_tables):
    """shift>0 traces exactly the messages the host-side predicate
    selects — the device hash and `sampled_fids` agree."""
    r = _traced_run(sf5_tables, trace_sample_shift=2,
                    trace_capacity=1 << 14)
    snap = r.telemetry
    msgs = np.unique(snap.events["msg"])
    assert 0 < len(msgs) < r.n_messages          # a strict subset
    assert sampled_fids(msgs, 2).all()
    # and nothing selected was silently skipped: every sampled message
    # that delivered flits appears in the trace
    want = np.flatnonzero(sampled_fids(np.arange(r.n_messages), 2))
    done = want[np.asarray(r.msg_done)[want] >= 0]
    assert np.isin(done, msgs).all()
    # re-running is bit-identical (hash sampling, no RNG)
    r2 = _traced_run(sf5_tables, trace_sample_shift=2,
                     trace_capacity=1 << 14)
    np.testing.assert_array_equal(snap.events, r2.telemetry.events)


# ---------------------------------------------------------------------------
# export layer
# ---------------------------------------------------------------------------

def test_export_chrome_trace_and_heatmap(sf5_tables, tmp_path):
    r = _traced_run(sf5_tables, trace_sample_shift=1,
                    trace_capacity=1 << 14)
    doc = export.chrome_trace(r.telemetry,
                              per_cycle_counter=r.per_cycle_delivered)
    json.loads(json.dumps(doc))                  # fully serialisable
    evs = doc["traceEvents"]
    assert any(e["ph"] == "X" for e in evs)      # flit spans
    assert any(e["ph"] == "M" for e in evs)      # track metadata
    assert any(e["ph"] == "C" for e in evs)      # run counter track
    assert doc["otherData"]["n_spans"] > 0
    p = tmp_path / "trace.json"
    export.write_chrome_trace(str(p), r.telemetry)
    assert json.loads(p.read_text())["traceEvents"]

    hp = tmp_path / "heat.json"
    hdoc = export.write_channel_heatmap(
        str(hp), [r.telemetry], lane_labels=["run"])
    loaded = json.loads(hp.read_text())
    assert loaded["kind"] == "repro.telemetry.channel_load"
    lane = loaded["lanes"][0]
    assert lane["label"] == "run"
    load = np.asarray(lane["channel_load"])
    assert load.shape == np.asarray(sf5_tables.nbr).shape
    assert (load >= 0).all() and (load <= 1).all()
    assert hdoc["n_lanes"] == 1

    lines = export.telemetry_summary(r.telemetry.counters, top=3)
    assert any("channel" in ln for ln in lines)


# ---------------------------------------------------------------------------
# SimResult.saturated derives from the configured q_src (satellite fix)
# ---------------------------------------------------------------------------

def test_saturated_uses_configured_q_src():
    def mk(occ, q_src):
        return SimResult(
            name="t", offered_load=0.5, accepted_load=0.4,
            avg_latency=1.0, delivered=1, injected=1,
            dropped_at_source=0, src_occupancy=occ,
            per_cycle_delivered=np.zeros(1), q_src=q_src)
    # occupancy 20: saturated for a depth-8 queue, fine for depth-64
    assert mk(20.0, 8).saturated
    assert not mk(20.0, 64).saturated
    # any source drop saturates regardless of depth
    r = dataclasses.replace(mk(0.0, 64), dropped_at_source=3)
    assert r.saturated


def test_simulate_plumbs_q_src(sf5_tables):
    uni = make_traffic(sf5_tables, "uniform")
    r = simulate(sf5_tables, uni, SimConfig(
        injection_rate=0.1, cycles=40, warmup=10, q_src=16))
    assert r.q_src == 16
