"""Failure-aware routing + degraded-mode simulation (DESIGN.md §8).

Covers the ISSUE-3 acceptance criteria on SF MMS q=5 with 10% random
link failures: full reroute success while connected, deadlock-freedom
of the degraded MIN+VAL path set the engine uses, and a finite
closed-loop all-reduce makespan on the degraded SimTables — plus the
zero-mask exactness and channel-load property tests.
"""

import numpy as np
import pytest

# hypothesis when installed, deterministic fallback otherwise
from _hypothesis_compat import given, settings, st

from repro.core import build_slimfly
from repro.core.resiliency import failure_edge_sample
from repro.core.routing import (
    UNREACH,
    analytic_channel_load,
    build_routing,
    channel_load_uniform,
    is_deadlock_free,
    routed_resiliency_metrics,
    valiant_path,
)
from repro.dist.topology_aware import FabricModel
from repro.sim import SimConfig, SimTables, make_traffic, simulate
from repro.sim.workloads import (
    WorkloadSimConfig,
    ring_all_reduce,
    run_workload,
)


@pytest.fixture(scope="module")
def sf5():
    return build_slimfly(5)


@pytest.fixture(scope="module")
def mask10(sf5):
    """10% random link failures that keep the fabric connected."""
    for seed in range(20):
        fe = failure_edge_sample(sf5, 0.10,
                                 np.random.default_rng(seed))
        rt = build_routing(sf5, use_pallas=False, failed_edges=fe)
        if rt.reachable.all():
            return fe, rt
    pytest.fail("no connected 10% sample in 20 seeds")


# -- routed metrics ----------------------------------------------------------

def test_reroute_success_full_while_connected(sf5, mask10):
    """Acceptance: 10% failures, fabric connected => 100% reroute
    success, with bounded stretch and load inflation >= 1."""
    fe, _ = mask10
    m = routed_resiliency_metrics(sf5, fe, use_pallas=False)
    assert m.connected
    assert m.reroute_success == 1.0
    assert 1.0 <= m.mean_stretch <= m.max_stretch < np.inf
    assert m.load_inflation >= 1.0


def test_zero_failure_mask_reproduces_healthy_exactly(sf5):
    rt = build_routing(sf5, use_pallas=False)
    rt0 = build_routing(sf5, use_pallas=False,
                        failed_edges=np.zeros((0, 2), np.int32))
    assert (rt0.dist == rt.dist).all()
    assert (rt0.next_hop == rt.next_hop).all()
    assert rt0.reachable.all()
    m = routed_resiliency_metrics(sf5, np.zeros((0, 2), np.int32),
                                  base_rt=rt, use_pallas=False)
    assert m.reroute_success == 1.0
    assert m.mean_stretch == m.max_stretch == 1.0
    assert m.load_inflation == m.max_load_inflation == 1.0


@settings(max_examples=6, deadline=None)
@given(q=st.sampled_from([5, 7, 9]))
def test_channel_load_matches_analytic_property(q):
    """§II-B2 property: empirical mean MIN channel load == closed form
    l = (2 N_r - k' - 2) p^2 / k' on every Slim Fly."""
    topo = build_slimfly(q)
    rt = build_routing(topo, use_pallas=False)
    avg, _ = channel_load_uniform(rt)
    expected = analytic_channel_load(topo.network_radix, topo.n_routers,
                                     topo.p)
    assert abs(avg - expected) / expected < 1e-9


def test_degraded_dist_monotone_and_sentinel(sf5, mask10):
    fe, rt_f = mask10
    rt = build_routing(sf5, use_pallas=False)
    assert (rt_f.dist >= rt.dist).all()          # failures never shorten
    # cut one router completely off: its pairs must hit the sentinel
    victim = 0
    nbrs = np.nonzero(sf5.adj[victim])[0]
    cut = np.stack([np.full_like(nbrs, victim), nbrs], axis=1)
    rt_cut = build_routing(sf5, use_pallas=False, failed_edges=cut)
    assert (rt_cut.dist[victim, 1:] == UNREACH).all()
    assert (rt_cut.next_hop[victim, 1:] == -1).all()
    assert not rt_cut.reachable[victim, 1]


# -- degraded SimTables ------------------------------------------------------

def test_degraded_tables_dead_ports_and_consistency(sf5, mask10):
    fe, _ = mask10
    healthy = SimTables.build(sf5)
    deg = SimTables.build(sf5, failed_edges=fe)
    assert deg.P == healthy.P and deg.nbr.shape == healthy.nbr.shape
    # exactly the failed links became -1 pads, in both directions
    assert ((healthy.nbr >= 0).sum() - (deg.nbr >= 0).sum()) == 2 * len(fe)
    dead = set(map(tuple, np.sort(fe, axis=1)))
    n = sf5.n_routers
    for r in range(n):
        for o in range(deg.P):
            v_h, v_d = healthy.nbr[r, o], deg.nbr[r, o]
            if v_h >= 0 and (min(r, v_h), max(r, v_h)) in dead:
                assert v_d == -1
            else:
                assert v_d == v_h                # live ports keep their id
    # port_toward only aims at live ports and makes distance progress
    for r in range(n):
        for t in range(n):
            o = deg.port_toward[r, t]
            if o >= 0:
                v = deg.nbr[r, o]
                assert v >= 0
                assert deg.dist[v, t] == deg.dist[r, t] - 1


def test_degraded_min_val_paths_deadlock_free(sf5, mask10):
    """Acceptance: the MIN+VAL path set the engine uses on the degraded
    fabric stays deadlock-free under hop-indexed VCs."""
    fe, rt = mask10
    n = sf5.n_routers
    paths = [rt.min_path(s, d) for s in range(n) for d in range(n)
             if s != d]
    rng = np.random.default_rng(0)
    for _ in range(300):
        s, d, r = (int(x) for x in rng.integers(0, n, 3))
        if rt.dist[s, r] < UNREACH and rt.dist[r, d] < UNREACH:
            paths.append(valiant_path(rt, s, d, r))
    assert is_deadlock_free(paths, n)


# -- degraded engines --------------------------------------------------------

def test_closed_loop_completes_on_degraded_fabric(sf5, mask10):
    """Acceptance: ring all-reduce finishes with finite makespan on the
    degraded SimTables, and no faster than on the healthy fabric."""
    fe, _ = mask10
    wl = ring_all_reduce(8, 2)
    cfg = WorkloadSimConfig(mode="min", chunk=128)
    healthy = run_workload(SimTables.build(sf5), wl, cfg)
    degraded = run_workload(SimTables.build(sf5, failed_edges=fe), wl, cfg)
    assert degraded.completed and np.isfinite(degraded.makespan)
    assert degraded.makespan >= healthy.makespan
    assert degraded.flits_delivered == int(wl.size.sum())


def test_open_loop_modes_deliver_on_degraded_fabric(sf5, mask10):
    fe, _ = mask10
    tables = SimTables.build(sf5, failed_edges=fe)
    for mode in ("min", "ugal_l", "val"):
        r = simulate(tables, make_traffic(tables, "uniform"),
                     SimConfig(injection_rate=0.05, cycles=300,
                               warmup=100, mode=mode))
        assert r.delivered > 0, mode
        # flit conservation still holds on the degraded fabric
        assert (np.cumsum(r.per_cycle_injected)
                == np.cumsum(r.per_cycle_delivered)
                + r.per_cycle_in_flight).all(), mode


def test_transient_mask_ecmp_fallback_delivers(sf5, mask10):
    """rebuild=False keeps stale routes; the engine's dead-port ECMP
    fallback must still deliver traffic around the dead links."""
    fe, _ = mask10
    tables = SimTables.build(sf5, ecmp=True).with_failures(
        fe[:3], rebuild=False)
    assert (tables.nbr >= 0).sum() == 2 * (sf5.n_edges - 3)
    r = simulate(tables, make_traffic(tables, "uniform"),
                 SimConfig(injection_rate=0.05, cycles=300, warmup=100,
                           mode="min"))
    assert r.delivered > 0


# -- degraded FabricModel ----------------------------------------------------

def test_fabric_model_degrades_consistently(sf5, mask10):
    fe, _ = mask10
    healthy = FabricModel(sf5)
    degraded = FabricModel(sf5, failed_edges=fe)
    assert degraded.topo.n_edges == sf5.n_edges - len(fe)
    group = np.arange(16)
    h = healthy.estimate("all_reduce", 1 << 20, group)
    d = degraded.estimate("all_reduce", 1 << 20, group)
    # fewer links + longer hops can only slow the estimate down
    for alg in ("ring", "direct"):
        assert d[alg].time_s >= h[alg].time_s * (1 - 1e-12)
        assert d[alg].mean_hops >= h[alg].mean_hops
    # zero mask is the identity
    same = FabricModel(sf5, failed_edges=np.zeros((0, 2), np.int32))
    assert same.topo is sf5
