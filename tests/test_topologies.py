"""Structural invariants of the Slim Fly construction and the comparison
topologies (paper §II, §III, Table II)."""

import numpy as np
import pytest

# hypothesis when installed, deterministic fallback otherwise
from _hypothesis_compat import given, settings, st

from repro.core import (
    GF,
    balanced_concentration,
    build_slimfly,
    enumerate_slimfly_configs,
    moore_bound,
    slimfly_params,
    valid_q,
)
from repro.core.topologies import (
    build_dln,
    build_dragonfly,
    build_fattree3,
    build_flattened_butterfly,
    build_hypercube,
    build_longhop_hc,
    build_polarity_graph,
    build_torus,
    dragonfly_for_radix,
)

SF_QS = [4, 5, 7, 8, 9, 11, 13, 16, 17, 19]


# ------------------------------------------------------------ finite field --
@pytest.mark.parametrize("q", [2, 3, 4, 5, 7, 8, 9, 16, 25, 27])
def test_gf_field_axioms(q):
    f = GF(q)
    idx = np.arange(q)
    # additive/multiplicative identities
    np.testing.assert_array_equal(f.add_table[0], idx)
    np.testing.assert_array_equal(f.mul_table[1], idx)
    # every nonzero element has a multiplicative inverse (row is a permutation)
    for a in range(1, q):
        assert sorted(f.mul_table[a, 1:].tolist()) != sorted([0] * (q - 1))
        assert 1 in f.mul_table[a, 1:]
    # primitive element has order q-1
    assert sorted(f.powers(f.xi, q - 1)) == list(range(1, q))


# ----------------------------------------------------------------- SF MMS --
@pytest.mark.parametrize("q", SF_QS)
def test_slimfly_structure(q):
    t = build_slimfly(q)
    par = slimfly_params(q)
    assert t.n_routers == 2 * q * q
    assert (t.degrees == par["kprime"]).all()          # k'-regular
    assert t.diameter() == 2                            # the headline claim
    assert t.n_edges == par["kprime"] * t.n_routers // 2


def test_slimfly_q19_matches_paper_flagship():
    """§VI-A example: q=19 => 10830 endpoints, k'=29, p=15, k=44, N_r=722."""
    par = slimfly_params(19)
    assert par["kprime"] == 29
    assert par["n_routers"] == 722
    assert par["p"] == 15
    assert par["router_radix"] == 44
    assert par["n_endpoints"] == 10830


def test_hoffman_singleton():
    """q=5 yields the Hoffman–Singleton graph: 50 vertices, 175 edges,
    7-regular, diameter 2, girth 5 (Moore graph — meets the bound)."""
    t = build_slimfly(5)
    assert t.n_routers == 50 and t.n_edges == 175
    assert (t.degrees == 7).all() and t.diameter() == 2
    assert t.n_routers == moore_bound(7, 2)  # 1 + 7 + 7*6 = 50
    # girth 5: no triangles and no 4-cycles
    a = t.adj.astype(np.int64)
    assert np.trace(a @ a @ a) == 0
    paths2 = a @ a
    np.fill_diagonal(paths2, 0)
    assert (paths2[t.adj] == 0).all()  # adjacent pair with 2-path => C4... triangle
    assert (paths2[~t.adj] <= 1).all()  # two 2-paths between non-adj => C4


def test_moore_bound_proximity():
    """Fig 5a: SF MMS sits within ~12% of the Moore bound (paper reports
    N_r = 8192 vs MB 9217 at k' = 96, i.e. 8/9 asymptotically)."""
    for q in [17, 19, 25]:
        par = slimfly_params(q)
        mb = moore_bound(par["kprime"], 2)
        assert par["n_routers"] / mb > 0.85


def test_balanced_concentration_formula():
    """§II-B2: p ~= ceil(k'/2) (within 1 for small networks)."""
    for q in SF_QS:
        par = slimfly_params(q)
        assert abs(par["p"] - int(np.ceil(par["kprime"] / 2))) <= 1


def test_enumerate_library():
    """§VII-A claims 11 balanced SF variants below 20k endpoints."""
    lib = enumerate_slimfly_configs(20_000)
    assert len(lib) >= 10
    qs = [c["q"] for c in lib]
    assert qs == sorted(qs)
    assert all(c["n_endpoints"] <= 20_000 for c in lib)


@settings(max_examples=10, deadline=None)
@given(q=st.sampled_from(SF_QS), seed=st.integers(0, 1000))
def test_slimfly_two_hop_property(q, seed):
    """Property: ANY pair of routers is connected by a path of length <= 2
    — sampled pairs checked against the adjacency directly."""
    t = build_slimfly(q)
    rng = np.random.default_rng(seed)
    a, b = rng.integers(0, t.n_routers, 2)
    adj = t.adj
    ok = (a == b) or adj[a, b] or bool((adj[a] & adj[b]).any())
    assert ok


# --------------------------------------------- paper-scale properties --
# (the sizes the scaled simulator targets — DESIGN.md §9)
PAPER_QS = [7, 11, 17]


@pytest.mark.parametrize("q", PAPER_QS)
def test_paper_scale_structure_matches_params(q):
    """Radix / router / endpoint counts of the built network equal
    `slimfly_params`, and the MMS diameter-2 claim holds at every
    simulator target size — verified through the Pallas min-plus APSP
    (the same kernel the analysis pipeline uses)."""
    from conftest import cached_slimfly
    from repro.kernels import INF, apsp

    t = cached_slimfly(q)
    par = slimfly_params(q)
    assert t.n_routers == par["n_routers"]
    assert t.network_radix == par["kprime"]
    assert (t.degrees == par["kprime"]).all()
    assert t.p == par["p"]
    assert t.n_endpoints == par["n_endpoints"]
    assert t.router_radix == par["router_radix"]

    d = np.array(apsp(t.adj, max_diameter=4, use_pallas=True))
    assert (d < INF / 10).all()              # connected
    np.fill_diagonal(d, 0)
    assert int(d.max()) == 2                 # the headline claim


@settings(max_examples=12, deadline=None)
@given(q=st.sampled_from(PAPER_QS), seed=st.integers(0, 10_000))
def test_paper_scale_two_hop_property(q, seed):
    """Sampled-pair 2-hop reachability at the simulator target sizes
    (hypothesis when installed, deterministic fallback otherwise)."""
    from conftest import cached_slimfly

    t = cached_slimfly(q)
    rng = np.random.default_rng(seed)
    a, b = rng.integers(0, t.n_routers, 2)
    adj = t.adj
    assert (a == b) or adj[a, b] or bool((adj[a] & adj[b]).any())


# ------------------------------------------------- comparison topologies --
def test_dragonfly_paper_configs():
    """§V: DF k=27, p=7 => N_r=1386, N=9702; Table IV: k=43 => 5346/58806."""
    df = build_dragonfly(h=7)
    assert df.n_routers == 1386 and df.n_endpoints == 9702
    assert df.router_radix == 27 and df.diameter() == 3
    df43 = dragonfly_for_radix(43)
    assert df43.n_routers == 5346 and df43.n_endpoints == 58806


def test_fattree3_paper_config():
    """§V: FT-3 k=44, p=22 => N_r=1452, N=10648, diameter 4."""
    ft = build_fattree3(44)
    assert ft.n_routers == 1452 and ft.n_endpoints == 10648
    assert ft.diameter() == 4


def test_fbf3_structure():
    fb = build_flattened_butterfly(6, 3)
    assert fb.n_routers == 216 and fb.diameter() == 3
    assert (fb.degrees == 3 * 5).all()
    fb2 = build_flattened_butterfly(8, 2)
    assert fb2.diameter() == 2


def test_torus_diameters():
    """Table II: T3D diameter = 3/2 * cbrt(N_r) (even radix: 3 * r/2)."""
    t = build_torus(6, 3)
    assert t.diameter() == 3 * 3  # 3 dims * floor(6/2)
    t5 = build_torus(4, 5)
    assert t5.diameter() == 5 * 2


def test_hypercube_diameter():
    hc = build_hypercube(8)
    assert hc.diameter() == 8 and (hc.degrees == 8).all()


def test_dln_regular_and_low_diameter():
    d = build_dln(338, 4, seed=1)
    assert (d.degrees == 6).all()
    assert 3 <= d.diameter() <= 10  # paper Table II range


def test_longhop_bisection_oriented():
    lh = build_longhop_hc(9)
    assert lh.n_routers == 512
    assert lh.network_radix == 9 + 4


def test_polarity_graph():
    """P_u: u^2+u+1 vertices, degree u or u+1, diameter 2 (BDF block)."""
    for u in [3, 4, 5, 7]:
        g = build_polarity_graph(u)
        assert g.n_routers == u * u + u + 1
        assert g.diameter() == 2
        degs = set(g.degrees.tolist())
        assert degs <= {u, u + 1}


def test_average_hops_ordering():
    """Fig 1: SF has the lowest average endpoint-to-endpoint hop count."""
    sf = build_slimfly(7)            # N=588
    df = build_dragonfly(h=3)        # N=570
    ft = build_fattree3(p=9)         # N=729
    h_sf = sf.average_endpoint_hops()
    h_df = df.average_endpoint_hops()
    h_ft = ft.average_endpoint_hops()
    assert h_sf < h_df < h_ft
    assert h_sf < 2.0


def test_bdf_star_product_diameter3():
    """§II-C: P_u * K_n has diameter 3 (BDF construction realized)."""
    from repro.core.topologies import build_bdf
    for u in [3, 4, 5]:
        t = build_bdf(u)
        assert t.diameter() == 3
        assert t.n_routers == (u * u + u + 1) * max(2, (u + 3) // 2)


def test_slimfly_as_dragonfly_groups():
    """§VII-B: SF groups inside a Dragonfly — diameter <= 2(SF) + 1(global)
    + 2(SF) = 5, and much lower than a flat ring of the same size."""
    from repro.core.topologies import slimfly_dragonfly
    t = slimfly_dragonfly(5, n_groups=4, links_per_pair=2)
    assert t.n_routers == 200
    assert t.is_connected()
    assert t.diameter() <= 5
