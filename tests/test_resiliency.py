"""Resiliency under random link failures (§III-D, Table III)."""

import numpy as np
import pytest

from repro.core import build_slimfly
from repro.core.resiliency import (
    failure_sample,
    max_tolerated_fraction,
    metric_after_failures,
    resilience_sweep,
)
from repro.core.topologies import build_dragonfly, build_torus


def test_failure_sample_removes_expected_edges():
    topo = build_slimfly(5)
    rng = np.random.default_rng(0)
    adj = failure_sample(topo, 0.2, rng)
    removed = topo.n_edges - int(adj.sum()) // 2
    assert removed == int(0.2 * topo.n_edges)
    assert (adj == adj.T).all()


def test_zero_failures_always_survive():
    topo = build_slimfly(5)
    rate = metric_after_failures(topo, 0.0, "disconnect", n_samples=3)
    assert rate == 1.0


def test_kernel_engine_agrees_with_scipy():
    topo = build_slimfly(5)
    for metric in ["disconnect", "diameter"]:
        r_scipy = metric_after_failures(topo, 0.3, metric, n_samples=6,
                                        seed=42, engine="scipy")
        r_kernel = metric_after_failures(topo, 0.3, metric, n_samples=6,
                                         seed=42, engine="kernel")
        assert r_scipy == r_kernel


def test_slimfly_more_resilient_than_torus():
    """Table III ordering: SF >> T3D at comparable size."""
    sf = build_slimfly(5)                       # 50 routers, k'=7
    t3 = build_torus(4, 3)                      # 64 routers, k'=6
    sf_sweep = resilience_sweep(sf, "disconnect", n_samples=10, seed=1)
    t3_sweep = resilience_sweep(t3, "disconnect", n_samples=10, seed=1)
    assert max_tolerated_fraction(sf_sweep) > max_tolerated_fraction(t3_sweep)


def test_slimfly_beats_dragonfly_resilience():
    """§III-D1: SF tolerates at least as many failures as a same-scale DF."""
    sf = build_slimfly(7)                       # 98 routers
    df = build_dragonfly(h=3)                   # 114 routers
    sf_r = max_tolerated_fraction(
        resilience_sweep(sf, "disconnect", n_samples=10, seed=3))
    df_r = max_tolerated_fraction(
        resilience_sweep(df, "disconnect", n_samples=10, seed=3))
    assert sf_r >= df_r


def test_diameter_metric_stricter_than_disconnect():
    topo = build_slimfly(7)
    dis = max_tolerated_fraction(
        resilience_sweep(topo, "disconnect", n_samples=8, seed=5))
    dia = max_tolerated_fraction(
        resilience_sweep(topo, "diameter", n_samples=8, seed=5))
    assert dia <= dis
