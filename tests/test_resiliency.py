"""Resiliency under random link failures (§III-D, Table III)."""

import numpy as np
import pytest

from repro.core import build_slimfly
from repro.core.resiliency import (
    failure_sample,
    max_tolerated_fraction,
    metric_after_failures,
    resilience_sweep,
)
from repro.core.topologies import build_dragonfly, build_torus


def test_failure_sample_removes_expected_edges():
    topo = build_slimfly(5)
    rng = np.random.default_rng(0)
    adj = failure_sample(topo, 0.2, rng)
    removed = topo.n_edges - int(adj.sum()) // 2
    assert removed == int(0.2 * topo.n_edges)
    assert (adj == adj.T).all()


def test_zero_failures_always_survive():
    topo = build_slimfly(5)
    rate = metric_after_failures(topo, 0.0, "disconnect", n_samples=3)
    assert rate == 1.0


def test_kernel_engine_agrees_with_scipy():
    topo = build_slimfly(5)
    for metric in ["disconnect", "diameter"]:
        r_scipy = metric_after_failures(topo, 0.3, metric, n_samples=6,
                                        seed=42, engine="scipy")
        r_kernel = metric_after_failures(topo, 0.3, metric, n_samples=6,
                                         seed=42, engine="kernel")
        assert r_scipy == r_kernel


def test_slimfly_more_resilient_than_torus():
    """Table III ordering: SF >> T3D at comparable size."""
    sf = build_slimfly(5)                       # 50 routers, k'=7
    t3 = build_torus(4, 3)                      # 64 routers, k'=6
    sf_sweep = resilience_sweep(sf, "disconnect", n_samples=10, seed=1)
    t3_sweep = resilience_sweep(t3, "disconnect", n_samples=10, seed=1)
    assert max_tolerated_fraction(sf_sweep) > max_tolerated_fraction(t3_sweep)


def test_slimfly_beats_dragonfly_resilience():
    """§III-D1: SF tolerates at least as many failures as a same-scale DF."""
    sf = build_slimfly(7)                       # 98 routers
    df = build_dragonfly(h=3)                   # 114 routers
    sf_r = max_tolerated_fraction(
        resilience_sweep(sf, "disconnect", n_samples=10, seed=3))
    df_r = max_tolerated_fraction(
        resilience_sweep(df, "disconnect", n_samples=10, seed=3))
    assert sf_r >= df_r


def test_max_tolerated_stops_at_first_dip():
    """Regression: a non-monotone sweep must NOT credit fractions beyond
    the first sub-threshold dip (the seed returned 0.15 here)."""
    sweep = {0.05: 1.0, 0.10: 0.2, 0.15: 0.8}
    assert max_tolerated_fraction(sweep, threshold=0.5) == 0.05


def test_max_tolerated_treats_missing_fractions_as_failed():
    """resilience_sweep stops early at the first rate-0.0 fraction; the
    absent tail must not (and cannot) be credited."""
    truncated = {0.05: 1.0, 0.10: 0.6, 0.15: 0.0}   # 0.20+ never tested
    assert max_tolerated_fraction(truncated) == 0.10
    # all-surviving prefix still returns the largest tested fraction
    assert max_tolerated_fraction({0.05: 1.0, 0.10: 0.9}) == 0.10


def test_sweep_includes_breaking_fraction():
    """The early-stop fraction itself (rate 0.0) is in the dict, so
    consumers see where the sweep ended."""
    topo = build_slimfly(5)
    sweep = resilience_sweep(topo, "disconnect", n_samples=5, seed=1,
                             fractions=np.array([0.05, 0.9, 0.95]))
    assert sweep[0.9] == 0.0
    assert 0.95 not in sweep


def test_metric_baselines_lazy(monkeypatch):
    """'disconnect' must not compute any APSP baseline; 'diameter' with
    base_diameter given must not recompute it (seed demanded both)."""
    import repro.core.resiliency as res

    calls = {"n": 0}
    orig = res._scipy_metrics

    def counting(adj):
        calls["n"] += 1
        return orig(adj)

    monkeypatch.setattr(res, "_scipy_metrics", counting)
    topo = build_slimfly(5)
    metric_after_failures(topo, 0.1, "disconnect", n_samples=3)
    assert calls["n"] == 3                     # samples only, no baseline
    calls["n"] = 0
    metric_after_failures(topo, 0.1, "diameter", n_samples=3,
                          base_diameter=2.0)
    assert calls["n"] == 3                     # given baseline reused


def test_diameter_metric_stricter_than_disconnect():
    topo = build_slimfly(7)
    dis = max_tolerated_fraction(
        resilience_sweep(topo, "disconnect", n_samples=8, seed=5))
    dia = max_tolerated_fraction(
        resilience_sweep(topo, "diameter", n_samples=8, seed=5))
    assert dia <= dis
