"""Use hypothesis when installed; otherwise a minimal deterministic
fallback so the property-test modules still COLLECT AND RUN from a
clean environment (hypothesis is a dev extra, see requirements-dev.txt).

The fallback implements just what this repo's tests use — ``@given``
with keyword strategies ``st.integers`` / ``st.sampled_from`` and
``@settings(max_examples=..., deadline=...)`` — by running the test
body on ``max_examples`` pseudo-random draws from a per-test seeded
generator (crc32 of the test name, so failures reproduce)."""

from __future__ import annotations

import functools
import inspect
import zlib

try:                                     # real hypothesis, if available
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import numpy as _np

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample         # (rng) -> value

    class st:                            # noqa: N801 - mimic module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(
                lambda rng: seq[int(rng.integers(len(seq)))])

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                rng = _np.random.default_rng(
                    zlib.crc32(fn.__name__.encode()))
                for _ in range(n):
                    drawn = {k: s.sample(rng)
                             for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)
            # hide the drawn parameters from pytest's fixture
            # resolution: the wrapper itself takes only the fixtures
            # the ORIGINAL test declares beyond the strategies
            params = [p for name, p in
                      inspect.signature(fn).parameters.items()
                      if name not in strategies]
            wrapper.__signature__ = inspect.Signature(params)
            del wrapper.__wrapped__
            return wrapper
        return deco

    def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
