"""Every benchmark module's fast path must import, run, and emit sane
rows — catches import breakage (e.g. a missing repro.dist) and NaN/inf
regressions in derived values without asserting on the numbers."""

import math
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:
    sys.path.insert(0, REPO)

from benchmarks.run import MODULES  # noqa: E402


@pytest.mark.parametrize("modname", MODULES)
def test_benchmark_fast_mode(modname, monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_SMOKE", "1")   # sim-heavy modules shrink
    monkeypatch.delenv("REPRO_FULL", raising=False)
    # engine_scaling writes its BENCH json; keep the repo tree clean
    monkeypatch.setenv("REPRO_BENCH_OUT", str(tmp_path / "BENCH_engine.json"))
    mod = __import__(f"benchmarks.{modname}", fromlist=["run"])
    rows = mod.run(fast=True)
    assert isinstance(rows, list) and rows, f"{modname}: no rows"
    for row in rows:
        assert "name" in row, (modname, row)
        derived = row.get("derived", 0)
        assert isinstance(derived, (int, float)), (modname, row)
        assert math.isfinite(derived), (modname, row)
    if modname == "workloads_jct":
        # closed-loop JCT rows must cover all three fabrics, every
        # workload must drain its DAG, and the all-reduce rows carry
        # the FabricModel cross-check ratio
        names = " ".join(row["name"] for row in rows)
        for tag in ("/sf/", "/df/", "/ft3/"):
            assert tag in names, names
        assert all(row["completed"] for row in rows), rows
        ratios = [row["fabric_ratio"] for row in rows
                  if "fabric_ratio" in row]
        assert ratios and all(0.2 < r < 5.0 for r in ratios), ratios
    if modname == "multitenant":
        # multi-tenant interference rows: every fabric under pack AND
        # spread, per-job JCT rows with slowdown/p99-inflation vs the
        # isolated baseline, plus a collective-slowdown row per point
        names = " ".join(row["name"] for row in rows)
        for tag in ("/sf/", "/df/", "/ft3/"):
            assert tag in names, names
        for pol in ("/pack/", "/spread/"):
            assert pol in names, names
        assert all(row["completed"] for row in rows), rows
        per_job = [r for r in rows
                   if not r["name"].endswith("/collective")]
        coll = [r for r in rows if r["name"].endswith("/collective")]
        assert per_job and coll
        for row in per_job:
            assert row["derived"] > 0, row          # JCT cycles
            assert row["slowdown"] > 0.2, row
            assert math.isfinite(row["p99_inflation"]), row
            assert row["queue_delay"] >= 0, row
        for row in coll:
            # collective slowdown: mean per-job JCT inflation; >= ~1
            # up to small RNG-phase wobble, bounded by sanity above
            assert 0.5 < row["derived"] < 100.0, row
    if modname == "fig8_buffers":
        # both halves of the figure must be present and sane, at the
        # smoke sweep sizes (REPRO_SMOKE knob threaded through, like
        # every other sim benchmark)
        names = " ".join(row["name"] for row in rows)
        assert "fig8a/buffers/" in names and "fig8be/oversub/" in names
        assert sum("fig8a/" in row["name"] for row in rows) == 2
        for row in rows:
            assert 0.0 <= row["derived"] <= 1.0, row
            assert row["latency"] > 0, row
    if modname == "engine_scaling":
        names = [row["name"] for row in rows]
        assert "engine_scaling/q5" in names and "engine_scaling/q7" in names
        assert "engine_scaling/sweep_q5_fig6" in names
        for row in rows:
            assert row["derived"] > 0, row
            if row["name"].startswith("engine_scaling/q"):
                assert row["compile_s"] > 0, row
        import json
        doc = json.load(open(tmp_path / "BENCH_engine.json"))
        assert doc["schema"] == 1 and doc["suite"] == "engine_scaling"
        ent = doc["entries"]["engine/q5/ugal_l"]
        assert ent["cycles_per_sec"] > 0 and ent["cycles"] > 0
        # the lane-batched fig6 smoke sweep must record its gate metric
        # (bit-exactness vs the sequential loop is asserted inside the
        # benchmark itself before the entry is written)
        swp = doc["entries"]["sweep/q5/fig6-5pt"]
        assert swp["sweep_points_per_sec"] > 0
        assert swp["meta"]["lanes"] == 5
    if modname == "collective_search":
        # schedule search: >= 8 candidates scored per compiled launch
        # and the best-found schedule never loses to the ring baseline
        # riding in generation 0 (DESIGN.md §13)
        for row in rows:
            assert row["scored"] >= 8, row
            assert row["derived"] <= row["baseline"], row
            assert row["speedup"] >= 1.0, row
            assert row["schedules_per_sec"] > 0, row
    if modname == "faults_sweep":
        # routed resiliency rows plus a completed degraded-JCT row
        names = " ".join(row["name"] for row in rows)
        assert "/routed/" in names and "/jct/" in names, names
        jct = [row for row in rows if "/jct/" in row["name"]]
        assert jct and all(row["completed"] for row in jct), jct
        routed = [row for row in rows if "/routed/" in row["name"]]
        assert all(0.0 <= row["derived"] <= 1.0 for row in routed), routed
