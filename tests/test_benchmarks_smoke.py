"""Every benchmark module's fast path must import, run, and emit sane
rows — catches import breakage (e.g. a missing repro.dist) and NaN/inf
regressions in derived values without asserting on the numbers."""

import math
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:
    sys.path.insert(0, REPO)

from benchmarks.run import MODULES  # noqa: E402


@pytest.mark.parametrize("modname", MODULES)
def test_benchmark_fast_mode(modname, monkeypatch):
    monkeypatch.setenv("REPRO_SMOKE", "1")   # sim-heavy modules shrink
    monkeypatch.delenv("REPRO_FULL", raising=False)
    mod = __import__(f"benchmarks.{modname}", fromlist=["run"])
    rows = mod.run(fast=True)
    assert isinstance(rows, list) and rows, f"{modname}: no rows"
    for row in rows:
        assert "name" in row, (modname, row)
        derived = row.get("derived", 0)
        assert isinstance(derived, (int, float)), (modname, row)
        assert math.isfinite(derived), (modname, row)
