"""Network simulator (§V): conservation laws, routing-mode behaviour,
traffic patterns, and qualitative reproduction of the paper's Fig 6
orderings (full curves live in benchmarks/fig6_perf.py)."""

import dataclasses

import numpy as np
import pytest

from repro.core import build_slimfly
from repro.core.topologies import build_dragonfly, build_fattree3
from repro.sim import SimConfig, SimTables, make_traffic, simulate


@pytest.fixture(scope="module")
def sf5_tables():
    return SimTables.build(build_slimfly(5))


@pytest.fixture(scope="module")
def uni5(sf5_tables):
    return make_traffic(sf5_tables, "uniform")


def test_packet_conservation(sf5_tables, uni5):
    """injected = delivered + still-queued (nothing lost or duplicated)."""
    cfg = SimConfig(injection_rate=0.4, cycles=300, warmup=0, mode="min",
                    seed=3)
    r = simulate(sf5_tables, uni5, cfg)
    # run longer with zero injection impossible via config; instead check
    # delivered <= injected and the gap is bounded by total buffering
    assert r.delivered <= r.injected
    n_q_slots = (sf5_tables.n_routers * sf5_tables.P * cfg.vcs * cfg.q_net
                 + sf5_tables.n_endpoints * cfg.q_src)
    assert r.injected - r.delivered <= n_q_slots


@pytest.mark.parametrize("rate", [0.1, 0.9])
def test_flit_conservation_every_cycle(sf5_tables, uni5, rate):
    """Conservation at EVERY cycle prefix (not just at the end): flits
    injected so far == delivered so far + in flight right now, at low
    and at saturating load; refused (dropped-at-source) flits never
    enter the network."""
    cfg = SimConfig(injection_rate=rate, cycles=400, warmup=0, mode="min",
                    seed=1)
    r = simulate(sf5_tables, uni5, cfg)
    cum_inj = np.cumsum(r.per_cycle_injected)
    cum_dlv = np.cumsum(r.per_cycle_delivered)
    np.testing.assert_array_equal(cum_inj,
                                  cum_dlv + r.per_cycle_in_flight)
    # per-cycle streams are consistent with the aggregate counters
    assert int(cum_inj[-1]) == r.injected
    assert int(cum_dlv[-1]) == r.delivered
    assert int(r.per_cycle_dropped.sum()) == r.dropped_at_source
    assert (r.per_cycle_in_flight >= 0).all()
    if rate >= 0.9:
        assert r.saturated                 # the stressed regime really is


def test_low_load_latency_is_distance(sf5_tables, uni5):
    """At 5% load, avg latency ~ avg hops + pipeline constants (no
    queueing): must be < 5 cycles in our 1-cycle-per-stage model."""
    r = simulate(sf5_tables, uni5,
                 SimConfig(injection_rate=0.05, cycles=500, warmup=200))
    assert r.avg_latency < 5.0
    assert r.accepted_load == pytest.approx(0.05, abs=0.01)


def test_min_beats_val_latency_uniform(sf5_tables, uni5):
    """Fig 6a: VAL pays ~2x path length; MIN is lowest-latency."""
    rmin = simulate(sf5_tables, uni5,
                    SimConfig(injection_rate=0.2, cycles=500, warmup=200,
                              mode="min"))
    rval = simulate(sf5_tables, uni5,
                    SimConfig(injection_rate=0.2, cycles=500, warmup=200,
                              mode="val"))
    assert rmin.avg_latency < rval.avg_latency


def test_val_saturates_below_half(sf5_tables, uni5):
    """Fig 6a: VAL doubles link pressure => accepted < 50% at high load."""
    r = simulate(sf5_tables, uni5,
                 SimConfig(injection_rate=0.8, cycles=600, warmup=300,
                           mode="val"))
    assert r.accepted_load < 0.5


def test_min_high_throughput_uniform(sf5_tables, uni5):
    """Fig 6a: MIN keeps high accepted bandwidth under uniform traffic."""
    r = simulate(sf5_tables, uni5,
                 SimConfig(injection_rate=0.95, cycles=700, warmup=300,
                           mode="min", lookahead=8))
    assert r.accepted_load > 0.75


def test_worstcase_min_collapses(sf5_tables):
    """§V-C / Fig 6d: MIN throughput collapses on the adversarial pattern
    (the single Rx-Ry link bottleneck); VAL/UGAL recover it."""
    wc = make_traffic(sf5_tables, "worstcase_sf")
    rmin = simulate(sf5_tables, wc,
                    SimConfig(injection_rate=0.5, cycles=600, warmup=300,
                              mode="min"))
    rval = simulate(sf5_tables, wc,
                    SimConfig(injection_rate=0.5, cycles=600, warmup=300,
                              mode="val"))
    rugal = simulate(sf5_tables, wc,
                     SimConfig(injection_rate=0.5, cycles=600, warmup=300,
                               mode="ugal_l"))
    assert rmin.accepted_load < 0.15          # ~1/(p+1) = 0.2 ceiling
    assert rval.accepted_load > rmin.accepted_load * 2
    assert rugal.accepted_load > rmin.accepted_load * 2


def test_ugal_l_tracks_min_at_low_load(sf5_tables, uni5):
    """§V-A: UGAL-L ~ MIN at low load (queues empty => MIN chosen)."""
    rmin = simulate(sf5_tables, uni5,
                    SimConfig(injection_rate=0.1, cycles=500, warmup=200,
                              mode="min"))
    ru = simulate(sf5_tables, uni5,
                  SimConfig(injection_rate=0.1, cycles=500, warmup=200,
                            mode="ugal_l"))
    assert ru.avg_latency < rmin.avg_latency + 3.0


def test_bit_patterns_active_subset(sf5_tables):
    """§V-B: bit-permutation patterns activate a power-of-two subset."""
    for pat in ["shuffle", "bitrev", "bitcomp", "shift"]:
        t = make_traffic(sf5_tables, pat)
        n_act = int(t.active.sum())
        assert n_act == 128  # largest power of two <= 200
        r = simulate(sf5_tables, t,
                     SimConfig(injection_rate=0.15, cycles=400, warmup=150))
        assert r.accepted_load == pytest.approx(0.15, abs=0.03)


def test_dragonfly_sim_runs():
    """DF with generic UGAL-L (the paper's DF baseline)."""
    tables = SimTables.build(build_dragonfly(h=2))
    uni = make_traffic(tables, "uniform")
    r = simulate(tables, uni, SimConfig(injection_rate=0.2, cycles=400,
                                        warmup=150, mode="ugal_l"))
    assert r.accepted_load == pytest.approx(0.2, abs=0.04)
    assert r.avg_latency < 20


def test_fattree_ecmp_runs():
    """FT-3 with adaptive ECMP (ANCA stand-in)."""
    topo = build_fattree3(p=4)
    tables = SimTables.build(topo, ecmp=True)
    uni = make_traffic(tables, "uniform")
    r = simulate(tables, uni, SimConfig(injection_rate=0.3, cycles=400,
                                        warmup=150, mode="ecmp"))
    assert r.accepted_load == pytest.approx(0.3, abs=0.05)


def test_sf_latency_below_dragonfly():
    """Fig 6a headline: SF lower latency than DF (diameter 2 vs 3)."""
    sf_t = SimTables.build(build_slimfly(5))           # N=200
    df_t = SimTables.build(build_dragonfly(h=2))       # N=90
    r_sf = simulate(sf_t, make_traffic(sf_t, "uniform"),
                    SimConfig(injection_rate=0.2, cycles=500, warmup=200,
                              mode="min"))
    r_df = simulate(df_t, make_traffic(df_t, "uniform"),
                    SimConfig(injection_rate=0.2, cycles=500, warmup=200,
                              mode="ugal_l"))
    assert r_sf.avg_latency < r_df.avg_latency


def test_deterministic_given_seed(sf5_tables, uni5):
    cfg = SimConfig(injection_rate=0.3, cycles=200, warmup=50, seed=11)
    r1 = simulate(sf5_tables, uni5, cfg)
    r2 = simulate(sf5_tables, uni5, cfg)
    assert r1.delivered == r2.delivered
    assert r1.avg_latency == r2.avg_latency


def test_worstcase_seed_threaded(sf5_tables):
    """make_traffic threads `seed` into the worst-case link search (it
    used to be silently ignored); any seed yields a valid adversarial
    pattern and seed=0 stays deterministic."""
    t0a = make_traffic(sf5_tables, "worstcase_sf", seed=0)
    t0b = make_traffic(sf5_tables, "worstcase_sf", seed=0)
    np.testing.assert_array_equal(t0a.active, t0b.active)
    for seed in (0, 7):
        t = make_traffic(sf5_tables, "worstcase_sf", seed=seed)
        assert t.active.sum() > 0
        dst = np.asarray(t.sample(None))
        # active senders target other endpoints
        assert (dst[t.active] != np.arange(len(t.active))[t.active]).all()


def test_load_sweep_compiles_once(sf5_tables, uni5):
    """Injection rate and seed are traced operands: a load sweep over
    one (tables, traffic, static-config) reuses a single compiled scan
    instead of retracing per rate point (fig6 perf satellite)."""
    from repro.sim import engine

    engine._OPEN_LOOP_CACHE.clear()
    cfg0 = SimConfig(injection_rate=0.1, cycles=120, warmup=40, mode="min")
    for rate, seed in [(0.1, 0), (0.4, 1), (0.7, 2)]:
        r = simulate(sf5_tables, uni5, dataclasses.replace(
            cfg0, injection_rate=rate, seed=seed))
        assert r.accepted_load > 0
    assert len(engine._OPEN_LOOP_CACHE) == 1
