"""Explicit-path collective policy IR (DESIGN.md §13): the deadlock
checker rejects a hand-built cyclic path set under the clamped VC
assignment, `from_transfers` derives dependency triggers from chunk
ownership, source-routed MIN reproduces table-routed MIN per-message
latencies exactly (flit-conservation-clean), the policy round trip
lands inside the calibrated 2x FabricModel band, the routing-mode flag
keeps table/source compiles apart in the runner cache, lane-batched
schedule scoring is bit-exact vs sequential runs, and Poisson arrival
sampling stays plain data."""

import types

import numpy as np
import pytest
from conftest import cached_slimfly

from repro.core.routing import build_routing
from repro.dist.collectives import emit_policy
from repro.sim import SimTables
from repro.sim.sweep import sweep_run_policies
from repro.sim.workloads import (
    Job,
    PolicyDeadlockError,
    WorkloadSimConfig,
    fabric_crosscheck,
    from_transfers,
    place_ranks,
    poisson_arrivals,
    ring_all_reduce,
    run_jobs,
    run_workload,
    with_arrivals,
)

RANKS, CHUNK = 8, 16


@pytest.fixture(scope="module")
def sf5():
    topo = cached_slimfly(5)
    rt = build_routing(topo, use_pallas=False)
    tab = SimTables.build(topo, rt)
    ep = place_ranks(tab, RANKS, "linear")
    return topo, rt, tab, np.asarray(ep, dtype=np.int32)


def _ring_policy(rt, tab, ep, **kw):
    ror = tab.ep_router[ep].astype(np.int64)
    return emit_policy("ring_all_reduce", rt, RANKS, CHUNK, ror, **kw)


# ---------------------------------------------------------------------------
# deadlock-freedom checker (satellite: CDG under the clamped assignment)
# ---------------------------------------------------------------------------

# Triangle fabric: three routers, fully cyclic.  The detour path set
# {0->2->1, 1->0->2, 2->1->0} chains the three channels (0,2) (2,1)
# (1,0) into a directed CDG cycle when every hop shares one VC.
_TRI_ADJ = np.array([[0, 1, 1], [1, 0, 1], [1, 1, 0]], dtype=bool)
_TRI_PATHS = [(0, 2, 1), (1, 0, 2), (2, 1, 0)]


def _tri_policy():
    transfers = [(c, p[0], p[-1], 0, 4, p) for c, p in enumerate(_TRI_PATHS)]
    initial = [(c, p[0]) for c, p in enumerate(_TRI_PATHS)]
    return from_transfers("tri", 3, np.arange(3), transfers, initial)


def test_deadlock_cycle_rejected_single_vc():
    """The hand-built cyclic counterexample must be caught: with one VC
    the clamped assignment puts every hop on VC 0 and the triangle's
    channel-dependency cycle closes."""
    pol = _tri_policy()
    pol.validate(adj=_TRI_ADJ)
    with pytest.raises(PolicyDeadlockError, match="channel-dependency"):
        pol.check_deadlock_free(n_routers=3, vcs=1)


def test_deadlock_cycle_broken_by_hop_indexed_vcs():
    """Same paths, two VCs: hop h rides VC min(0 + h, 1), so every CDG
    edge climbs VC0 -> VC1 and no cycle can close."""
    _tri_policy().check_deadlock_free(n_routers=3, vcs=2)


def test_emit_policy_wires_deadlock_check():
    """emit_policy must refuse to emit a deadlocking schedule: a
    callable path_set that detours every ring send the wrong way round
    the triangle raises through emit_policy at vcs=1, passes at vcs=2,
    and check_deadlock=False bypasses the gate."""
    rt = types.SimpleNamespace(adj=_TRI_ADJ,
                               topo=types.SimpleNamespace(n_routers=3))
    detour = lambda s, d, rng: (s, 3 - s - d, d)     # via the third router
    emit = lambda **kw: emit_policy("ring_all_reduce", rt, 3, 4,
                                    np.arange(3), path_set=detour, **kw)
    with pytest.raises(PolicyDeadlockError):
        emit(vcs=1)
    emit(vcs=2)
    emit(vcs=1, check_deadlock=False)                # explicit bypass


# ---------------------------------------------------------------------------
# from_transfers: ownership-derived dependency triggers
# ---------------------------------------------------------------------------

def test_from_transfers_ownership_deps():
    """An entry fires when its source owns the chunk: initial owners
    get no deps, forwarded chunks dep on the entry that delivered them,
    and a source that never obtains the chunk is an error."""
    ror = np.arange(3)
    path = lambda s, d: (s, d) if _TRI_ADJ[s, d] else (s, 3 - s - d, d)
    pol = from_transfers(
        "fwd", 3, ror,
        [("c", 0, 1, 0, 4, path(0, 1)),      # owner sends
         ("c", 1, 2, 0, 4, path(1, 2))],     # forwards once delivered
        initial_owner=[("c", 0)])
    assert pol.entries[0].deps == ()
    assert pol.entries[1].deps == (0,)
    with pytest.raises(ValueError, match="never"):
        from_transfers("bad", 3, ror, [("c", 1, 2, 0, 4, path(1, 2))],
                       initial_owner=[("c", 0)])


# ---------------------------------------------------------------------------
# source-routed vs table-routed MIN: latency-identical, conservation-clean
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def min_runs(sf5):
    topo, rt, tab, ep = sf5
    wl = _ring_policy(rt, tab, ep).lower(tab, ep)
    kw = dict(mode="min", chunk=64, kernel_path="ref", seed=0)
    r_tab = run_workload(tab, wl, WorkloadSimConfig(**kw))
    r_src = run_workload(tab, wl, WorkloadSimConfig(routing="source", **kw))
    return wl, r_tab, r_src


def test_source_vs_table_min_latency_identical(min_runs):
    """On identical (MIN) paths the source-routed engine must reproduce
    the table-routed engine's per-message start/done cycles exactly —
    the explicit route_port operand encodes the very same next hops the
    tables would have produced, and everything else in the trace is
    shared."""
    wl, r_tab, r_src = min_runs
    assert r_tab.completed and r_src.completed
    assert r_src.makespan == r_tab.makespan
    np.testing.assert_array_equal(r_src.msg_start, r_tab.msg_start)
    np.testing.assert_array_equal(r_src.msg_done, r_tab.msg_done)


def test_source_mode_flit_conservation(min_runs):
    """Every injected flit ejects at its destination: delivered flits
    equal the policy's total in both modes (no flit lost to a bad
    route_port row or stray eject)."""
    wl, r_tab, r_src = min_runs
    total = int(wl.size.sum())
    assert r_tab.flits_delivered == total
    assert r_src.flits_delivered == total


def test_policy_roundtrip_within_fabric_band(min_runs, sf5):
    """emit_policy(ring_all_reduce) -> lower -> source-routed run lands
    within the calibrated 2x FabricModel cross-check band, like the
    message-DAG ring it lowers from."""
    topo, rt, tab, ep = sf5
    _, _, r_src = min_runs
    cc = fabric_crosscheck(topo, "all_reduce", RANKS * CHUNK, ep,
                           r_src.makespan)
    assert 0.5 <= cc["ratio"] <= 2.0, cc


# ---------------------------------------------------------------------------
# routing-mode flag in the static key (cache-collision regression)
# ---------------------------------------------------------------------------

def test_routing_mode_in_static_key():
    kw = dict(mode="min", chunk=64, kernel_path="ref")
    k_tab = WorkloadSimConfig(**kw).static_key()
    k_src = WorkloadSimConfig(routing="source", **kw).static_key()
    assert k_tab != k_src


def test_no_cache_collision_between_modes(sf5):
    """Regression: with `routing` missing from static_key, a
    table-routed compile would be replayed for a source-routed run of
    the same shapes and silently ignore the explicit paths.  A
    Valiant-style detour policy (strictly longer paths than MIN) must
    therefore finish LATER source-routed than the table run it shares
    every static shape with."""
    topo, rt, tab, ep = sf5

    def valiant(s, d, rng):
        nbrs = np.flatnonzero(rt.adj[s])
        m = int(nbrs[int(rng.integers(len(nbrs)))])
        if m == d:
            m = int(nbrs[0]) if int(nbrs[0]) != d else int(nbrs[1])
        return (s,) + tuple(rt.min_path(m, d))

    wl = _ring_policy(rt, tab, ep, path_set=valiant).lower(tab, ep)
    kw = dict(mode="min", chunk=64, kernel_path="ref", seed=0)
    r_tab = run_workload(tab, wl, WorkloadSimConfig(**kw))
    r_src = run_workload(tab, wl, WorkloadSimConfig(routing="source", **kw))
    assert r_tab.completed and r_src.completed
    # same DAG either way, but the detour hops are real only in source
    # mode: per-message completion must differ
    assert not np.array_equal(r_src.msg_done, r_tab.msg_done)
    assert r_src.makespan >= r_tab.makespan
    assert r_src.flits_delivered == r_tab.flits_delivered == \
        int(wl.size.sum())


# ---------------------------------------------------------------------------
# lane-batched schedule scoring: bit-exact vs sequential source runs
# ---------------------------------------------------------------------------

def test_sweep_policies_bitexact_vs_sequential(sf5):
    """Four heterogeneous candidates (chunking, path set, ordering all
    differ) scored in ONE lane-batched run must match four sequential
    source-routed `run_workload` calls bit-for-bit."""
    topo, rt, tab, ep = sf5
    genomes = [dict(), dict(n_chunks=2), dict(path_set="diverse",
                                              path_seed=1),
               dict(n_chunks=4, path_set="diverse", path_seed=2,
                    order_seed=7)]
    wls = [_ring_policy(rt, tab, ep, **g).lower(tab, ep) for g in genomes]
    cfg = WorkloadSimConfig(routing="source", mode="min", chunk=64,
                            kernel_path="ref", seed=0)
    lanes = sweep_run_policies(tab, wls, cfg)
    assert len(lanes) == len(wls)
    for wl, lane in zip(wls, lanes):
        ref = run_workload(tab, wl, cfg)
        assert lane.completed and ref.completed
        assert lane.makespan == ref.makespan
        assert lane.flits_delivered == ref.flits_delivered
        np.testing.assert_array_equal(lane.msg_start, ref.msg_start)
        np.testing.assert_array_equal(lane.msg_done, ref.msg_done)


# ---------------------------------------------------------------------------
# Poisson arrival sampling (satellite: jobs.py stays data-only)
# ---------------------------------------------------------------------------

def test_poisson_arrivals_shape():
    a = poisson_arrivals(64, rate=1e-2, seed=3, start=100)
    assert a.shape == (64,) and a.dtype == np.int64
    assert (a >= 100).all()
    assert (np.diff(a) >= 0).all()                   # cumulative => sorted
    np.testing.assert_array_equal(a, poisson_arrivals(64, 1e-2, seed=3,
                                                      start=100))
    # mean inter-arrival tracks 1/rate
    gaps = np.diff(poisson_arrivals(4096, 1e-2, seed=0).astype(float))
    assert 60 <= gaps.mean() <= 140                  # 1/rate = 100

def test_with_arrivals_restamps_jobs():
    wl = ring_all_reduce(RANKS, CHUNK)
    jobs = [Job(f"j{i}", wl, arrival=0) for i in range(3)]
    stamped = with_arrivals(jobs, arrivals="poisson", rate=1e-2, seed=1)
    arr = [j.arrival for j in stamped]
    assert arr == sorted(arr)
    np.testing.assert_array_equal(arr, poisson_arrivals(3, 1e-2, seed=1))
    with pytest.raises(ValueError):
        with_arrivals(jobs, arrivals="bursty")


def test_poisson_jobs_run_and_serialize(sf5):
    """Poisson-stamped jobs run through run_jobs (the arrival vector is
    plain data — one compile regardless of the sampled cycles) and no
    job starts before its arrival."""
    topo, rt, tab, ep = sf5
    wl = ring_all_reduce(RANKS, CHUNK)
    jobs = with_arrivals([Job("a", wl), Job("b", wl)],
                         arrivals="poisson", rate=5e-3, seed=2)
    mj = run_jobs(tab, jobs, WorkloadSimConfig(mode="min", chunk=64,
                                               kernel_path="ref"),
                  policy="pack")
    assert mj.completed
    for j, jr in zip(jobs, mj.jobs):
        assert jr.completed
        assert int(jr.msg_start.min()) >= j.arrival
