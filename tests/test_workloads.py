"""Closed-loop workload engine (DESIGN.md §7): IR builders, rank
placement, deadlock freedom of the routes the engine uses, DAG
conservation (every message delivered exactly once, finite makespan),
determinism, and the FabricModel cross-validation the acceptance
criterion pins at 2x."""

import functools

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import build_slimfly
from repro.core.layout import make_layout
from repro.core.routing import build_routing, is_deadlock_free, valiant_path
from repro.sim import SimTables
from repro.sim.workloads import (
    PLACEMENTS,
    WorkloadSimConfig,
    all_to_all,
    fabric_crosscheck,
    graph_scatter,
    place_ranks,
    recursive_doubling_all_reduce,
    ring_all_reduce,
    run_workload,
    stencil,
    summarize,
)
from repro.sim.workloads.closed_loop import WorkloadResult

RING_K, RING_CHUNK = 16, 8


@pytest.fixture(scope="module")
def sf5_tables():
    return SimTables.build(build_slimfly(5))


@pytest.fixture(scope="module")
def ring_run(sf5_tables):
    """One ring all-reduce JCT run shared by the sim-level tests."""
    wl = ring_all_reduce(RING_K, RING_CHUNK)
    cfg = WorkloadSimConfig(mode="min", chunk=128, seed=0)
    return wl, cfg, run_workload(sf5_tables, wl, cfg)


# ---------------------------------------------------------------------------
# IR builders
# ---------------------------------------------------------------------------

def _assert_acyclic_kahn(wl):
    """Independent acyclicity check (Kahn), not the id-order shortcut."""
    m = wl.n_messages
    indeg = np.array([len(d) for d in wl.deps])
    succs = [[] for _ in range(m)]
    for i, d in enumerate(wl.deps):
        for j in d:
            succs[j].append(i)
    stack = list(np.nonzero(indeg == 0)[0])
    seen = 0
    while stack:
        v = stack.pop()
        seen += 1
        for w in succs[v]:
            indeg[w] -= 1
            if indeg[w] == 0:
                stack.append(w)
    assert seen == m, "dependency cycle"


@pytest.mark.parametrize("wl_fn", [
    lambda: ring_all_reduce(8, 4),
    lambda: recursive_doubling_all_reduce(8, 16),
    lambda: all_to_all(6, 3),
    lambda: stencil((4, 4), 8, iters=3),
    lambda: stencil((3, 3, 2), 8, iters=2),
    lambda: graph_scatter(24, 8, iters=2, seed=1),
])
def test_builders_valid_dags(wl_fn):
    wl = wl_fn()
    wl.validate()
    _assert_acyclic_kahn(wl)
    dm = wl.dep_matrix()
    assert dm.shape[0] == wl.n_messages and dm.shape[1] >= 1
    assert (wl.size > 0).all() and (wl.src != wl.dst).all()


def test_ring_all_reduce_shape():
    k = 8
    wl = ring_all_reduce(k, 4)
    assert wl.n_messages == 2 * (k - 1) * k
    # each rank sends exactly 2(k-1) chunks; phases split at step k-1
    counts = np.bincount(wl.src, minlength=k)
    assert (counts == 2 * (k - 1)).all()
    assert set(np.unique(wl.phase)) == {0, 1}


def test_graph_scatter_degree_skew():
    wl = graph_scatter(64, 4, iters=1, skew=1.3, seed=3)
    deg = np.bincount(wl.src, minlength=64)
    # Zipf out-degrees: some fan-out well above the median hub-style
    assert deg.max() >= 4 * max(1, int(np.median(deg)))
    assert deg.min() >= 1


# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", PLACEMENTS)
def test_placement_injective(sf5_tables, scheme):
    eps = place_ranks(sf5_tables, 48, scheme, seed=2)
    assert len(np.unique(eps)) == 48
    assert eps.min() >= 0 and eps.max() < sf5_tables.n_endpoints


def test_placement_blocked_groups_by_router(sf5_tables):
    p = sf5_tables.p
    eps = place_ranks(sf5_tables, 4 * p, "blocked")
    routers = sf5_tables.ep_router[eps]
    # consecutive p-blocks of ranks land on a single router each
    for b in range(4):
        assert len(set(routers[b * p:(b + 1) * p])) == 1
    assert len(set(routers)) == 4


def test_placement_spread_distinct_routers(sf5_tables):
    n_epr = sf5_tables.n_endpoints // sf5_tables.p
    eps = place_ranks(sf5_tables, n_epr, "spread")
    assert len(set(sf5_tables.ep_router[eps])) == n_epr


@functools.lru_cache(maxsize=None)
def _prop_tables(q):
    # q=7 (N=98 routers) is expensive to build; share across draws
    return SimTables.build(build_slimfly(q))


@settings(max_examples=20, deadline=None)
@given(q=st.sampled_from([5, 7]), scheme=st.sampled_from(PLACEMENTS),
       full=st.sampled_from([False, True]), seed=st.integers(0, 7))
def test_placement_property_injective_convention(q, scheme, full, seed):
    """Property (satellite): every scheme returns an injective map into
    the p-endpoints-per-router numbering, for n_ranks both < and ==
    n_endpoints; n_ranks == n_endpoints is a permutation of the fabric
    (the total order the job layer slices)."""
    tables = _prop_tables(q)
    n_ep, p = tables.n_endpoints, tables.p
    n_ranks = n_ep if full else 1 + (seed * 9173 + q) % (n_ep - 1)
    eps = place_ranks(tables, n_ranks, scheme, seed=seed)
    assert eps.shape == (n_ranks,) and eps.dtype == np.int32
    assert len(np.unique(eps)) == n_ranks                 # injective
    assert eps.min() >= 0 and eps.max() < n_ep
    # endpoint numbering convention: endpoint e lives on router
    # ep_router[e], p consecutive endpoint ids per router
    routers = tables.ep_router[eps]
    assert np.array_equal(routers, tables.ep_router[::p][eps // p])
    if full:
        assert np.array_equal(np.sort(eps), np.arange(n_ep))
    if scheme == "blocked":
        # rack-ordering against make_layout: rack ids are
        # non-decreasing along rank order, and every complete p-block
        # of consecutive ranks shares one router
        racks = make_layout(tables.topo).rack_of[routers]
        assert (np.diff(racks) >= 0).all()
        nb = n_ranks // p
        if nb:
            blocks = routers[:nb * p].reshape(nb, p)
            assert (blocks == blocks[:, :1]).all()


def test_placement_random_is_seed_sensitive(sf5_tables):
    """Premise of the `_sweep_run_workload` guard (tested end-to-end in
    tests/test_sweep.py): `random` placement varies with the seed, so
    per-lane seeds cannot share one compiled placement silently."""
    a = place_ranks(sf5_tables, 32, "random", seed=0)
    b = place_ranks(sf5_tables, 32, "random", seed=1)
    assert not np.array_equal(a, b)


# ---------------------------------------------------------------------------
# deadlock freedom of the routes the engine uses (satellite)
# ---------------------------------------------------------------------------

def test_workload_routes_deadlock_free(sf5_tables):
    """MIN and VAL path sets for the messages the engine injects on SF
    q=5 keep the hop-indexed-VC channel dependency graph acyclic."""
    rt = build_routing(sf5_tables.topo, use_pallas=False)
    n = sf5_tables.n_routers
    rng = np.random.default_rng(0)

    pairs = set()
    for wl, scheme in [(ring_all_reduce(RING_K, RING_CHUNK), "spread"),
                       (graph_scatter(24, 4, iters=1, seed=2), "random")]:
        eps = place_ranks(sf5_tables, wl.n_ranks, scheme, seed=1)
        src_r = sf5_tables.ep_router[eps[wl.src]]
        dst_r = sf5_tables.ep_router[eps[wl.dst]]
        pairs |= set(zip(src_r.tolist(), dst_r.tolist()))

    paths = []
    for s, d in sorted(pairs):
        if s == d:
            continue
        paths.append(rt.min_path(s, d))
        # VAL through sampled intermediates, as route_decision draws them
        for _ in range(3):
            i = int(rng.integers(n))
            while i in (s, d):
                i = (i + 1) % n
            paths.append(valiant_path(rt, s, d, i))
    assert len(paths) > 4 * RING_K
    assert is_deadlock_free(paths, n)


# ---------------------------------------------------------------------------
# closed-loop engine invariants
# ---------------------------------------------------------------------------

def test_dag_conservation_and_finite_makespan(ring_run):
    """Every DAG message injected is delivered exactly once (per-flit
    counts match message sizes on both ends) and the makespan is
    finite."""
    wl, _, r = ring_run
    assert r.completed
    assert np.isfinite(r.makespan) and r.makespan > 0
    np.testing.assert_array_equal(r.msg_sent, wl.size)
    np.testing.assert_array_equal(r.msg_delivered, wl.size)
    assert r.flits_delivered == wl.total_flits
    assert int(r.per_cycle_delivered.sum()) == wl.total_flits
    # causality: nothing completes before it starts, deps before users
    assert (r.msg_start >= 0).all() and (r.msg_done > r.msg_start).all()
    dm = wl.dep_matrix()
    for mid in range(wl.n_messages):
        for d in dm[mid]:
            if d >= 0:
                assert r.msg_done[d] <= r.msg_start[mid] + 1


def test_dependency_serialization_orders_phases(ring_run):
    """Ring steps are dependency-serialized: mean completion time of
    all-gather-phase messages exceeds the reduce-scatter phase's."""
    wl, _, r = ring_run
    done = r.msg_done.astype(float)
    assert done[wl.phase == 1].mean() > done[wl.phase == 0].mean()


def test_closed_loop_deterministic(sf5_tables, ring_run):
    wl, cfg, r1 = ring_run
    r2 = run_workload(sf5_tables, wl, cfg)
    assert r1.makespan == r2.makespan
    np.testing.assert_array_equal(r1.msg_done, r2.msg_done)


# ---------------------------------------------------------------------------
# analytic cross-validation (acceptance criterion: within 2x)
# ---------------------------------------------------------------------------

def test_ring_all_reduce_matches_fabric_model(sf5_tables, ring_run):
    """Cycle-sim ring all-reduce makespan on SF q=5 agrees with the
    cycle-calibrated FabricModel ring estimate within 2x."""
    wl, _, r = ring_run
    cc = fabric_crosscheck(sf5_tables.topo, "all_reduce",
                           RING_K * RING_CHUNK, r.ep_of_rank, r.makespan)
    assert 0.5 <= cc["ratio"] <= 2.0, cc


# ---------------------------------------------------------------------------
# accounting regressions (PR 6 satellites)
# ---------------------------------------------------------------------------

def test_cycles_run_trimmed_to_makespan(ring_run):
    """Regression: completed runs used to report cycles_run rounded up
    to the chunk boundary, with up to chunk-1 trailing post-completion
    entries in per_cycle_delivered.  Both must be trimmed to the true
    makespan.  The fixture's makespan is deliberately NOT a multiple of
    cfg.chunk, so the pre-fix rounding is observable."""
    wl, cfg, r = ring_run
    assert r.completed
    assert int(r.makespan) % cfg.chunk != 0, \
        "fixture no longer exercises the rounding path; pick a new chunk"
    assert r.cycles_run == int(r.makespan)
    assert len(r.per_cycle_delivered) == r.cycles_run
    assert int(r.per_cycle_delivered.sum()) == wl.total_flits


def test_incomplete_run_reports_partial_bw(sf5_tables):
    """Regression: achieved_bw returned 0.0 whenever makespan was inf,
    so timed-out degraded runs plotted as zero bandwidth.  Incomplete
    runs must report delivered/cycles_run, and the report table must
    mark the distinction."""
    wl = ring_all_reduce(RING_K, RING_CHUNK)
    cfg = WorkloadSimConfig(mode="min", chunk=32, max_cycles=32, seed=0)
    r = run_workload(sf5_tables, wl, cfg)
    assert not r.completed and not np.isfinite(r.makespan)
    assert r.cycles_run == 32                    # no trimming: ran out
    assert r.flits_delivered > 0
    assert r.achieved_bw == pytest.approx(r.flits_delivered / 32)
    table = summarize(wl, r).table()
    assert "INCOMPLETE" in table
    assert "run did not complete" in table


def _fake_result(wl, msg_start, msg_done):
    return WorkloadResult(
        name=wl.name, mode="min", placement="linear", n_ranks=wl.n_ranks,
        n_messages=wl.n_messages, completed=True,
        makespan=float(msg_done.max()), cycles_run=int(msg_done.max()),
        flits_injected=wl.total_flits, flits_delivered=wl.total_flits,
        msg_size=wl.size, msg_phase=wl.phase,
        msg_sent=wl.size.copy(), msg_delivered=wl.size.copy(),
        msg_start=msg_start, msg_done=msg_done,
        per_cycle_delivered=np.zeros(int(msg_done.max()), np.int64),
        ep_of_rank=np.arange(wl.n_ranks, dtype=np.int32))


def test_summarize_shared_hist_edges(ring_run):
    """Regression: per-phase auto histogram ranges made hist_edges
    differ across phases (cross-phase comparison meaningless); every
    phase must share one set of edges spanning the whole run.

    The synthetic result gives the two ring phases DISJOINT latency
    ranges (phase 0 constant at 5, phase 1 spread over [2, 40]), so the
    pre-fix per-phase auto ranges are observably different."""
    wl = ring_all_reduce(4, 2)                   # 2 phases, 24 messages
    m = wl.n_messages
    start = np.arange(m, dtype=np.int64) + 1
    lat = np.where(wl.phase == 0, 5,
                   2 + (38 * np.arange(m)) // max(m - 1, 1))
    r = _fake_result(wl, start, start + lat)
    rep = summarize(wl, r)
    assert len(rep.phases) == 2
    edges0 = rep.phases[0].hist_edges
    assert edges0[0] == pytest.approx(lat.min())
    assert edges0[-1] == pytest.approx(lat.max())
    for ph in rep.phases[1:]:
        np.testing.assert_array_equal(ph.hist_edges, edges0)
    for ph in rep.phases:
        assert int(ph.hist_counts.sum()) == ph.n_completed

    # end-to-end on a real run: still one shared set of edges
    wl2, _, r2 = ring_run
    rep2 = summarize(wl2, r2)
    for ph in rep2.phases[1:]:
        np.testing.assert_array_equal(ph.hist_edges,
                                      rep2.phases[0].hist_edges)


def test_summarize_constant_latency_guard():
    """When EVERY completed latency is equal, the shared lo==hi range
    must widen instead of collapsing to zero-width edges."""
    wl = all_to_all(2, 4)                        # 2 messages, 4 flits
    m = wl.n_messages
    start = np.full(m, 5, dtype=np.int64)
    r = _fake_result(wl, start, start + 7)
    rep = summarize(wl, r)
    for ph in rep.phases:
        edges = ph.hist_edges
        assert np.isfinite(edges).all() and edges[0] < edges[-1]
        assert int(ph.hist_counts.sum()) == ph.n_completed
