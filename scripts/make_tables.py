"""Regenerate the EXPERIMENTS.md appendix tables from the sweep jsons.

  PYTHONPATH=src python scripts/make_tables.py >> EXPERIMENTS.md
"""

import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")


def load(name):
    path = os.path.join(ROOT, "results", name)
    return json.load(open(path)) if os.path.exists(path) else []


def key(r):
    return (r["arch"], r["shape"])


def fmt_row(r, base):
    if r.get("status") == "SKIP":
        return (f"| {r['arch']} | {r['shape']} | SKIP (full attention "
                f"@500k) | | | | | | |")
    b = base.get(key(r), {})
    bm = b.get("mfu")
    delta = (f"{r['mfu']/bm:.1f}x" if bm and r.get("mfu") else "—")
    return ("| {arch} | {shape} | {tc:.3f} | {tm:.3f} | {tcoll:.3f} | "
            "{bn} | {peak:.2f} | {mfu:.3f} | {d} |").format(
        arch=r["arch"], shape=r["shape"], tc=r["t_compute"],
        tm=r["t_memory"], tcoll=r["t_collective"], bn=r["bottleneck"],
        peak=r["peak_bytes_per_dev"] / 2**30, mfu=r["mfu"], d=delta)


def main():
    single = load("dryrun_single.json")
    base = {key(r): r for r in load("dryrun_single_baseline.json")
            if r.get("status") == "ok"}

    print("\n## Appendix A — roofline, all 40 cells, 16x16 mesh "
          "(optimized build)\n")
    print("| arch | shape | t_compute s | t_memory s | t_collective s | "
          "bottleneck | peak GiB/dev | mfu-bound | vs baseline |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in single:
        print(fmt_row(r, base))

    multi = load("dryrun_multipod.json")
    if multi:
        print("\n## Appendix B — multi-pod 2x16x16 (512 chips)\n")
        print("| arch | shape | bottleneck | peak GiB/dev | mfu-bound |")
        print("|---|---|---|---|---|")
        for r in multi:
            if r.get("status") == "SKIP":
                print(f"| {r['arch']} | {r['shape']} | SKIP | | |")
            else:
                print(f"| {r['arch']} | {r['shape']} | {r['bottleneck']} | "
                      f"{r['peak_bytes_per_dev']/2**30:.2f} | "
                      f"{r['mfu']:.3f} |")


if __name__ == "__main__":
    main()
