"""Run a closed-loop HPC workload on a simulated fabric and print the
JCT report (DESIGN.md §7).

  PYTHONPATH=src python examples/run_workload.py \\
      [--topo sf|df|ft3] [--workload ring_all_reduce|recdbl_all_reduce|
       all_to_all|stencil|graph_scatter] [--ranks 32] [--flits 8]
      [--mode min] [--placement linear]
"""

import argparse

from repro.core import build_slimfly
from repro.core.topologies import build_dragonfly, build_fattree3
from repro.sim import SimTables
from repro.sim.workloads import (
    PLACEMENTS,
    WorkloadSimConfig,
    fabric_crosscheck,
    make_workload,
    run_workload,
    summarize,
)


def build_tables(topo: str, q: int) -> SimTables:
    if topo == "sf":
        return SimTables.build(build_slimfly(q))
    if topo == "df":
        return SimTables.build(build_dragonfly(h=2))
    return SimTables.build(build_fattree3(p=4), ecmp=True)


def build_workload(kind: str, ranks: int, flits: int, iters: int):
    if kind == "stencil":
        # largest gx <= sqrt(ranks) with gx, ranks/gx both >= 2, so the
        # grid uses EXACTLY the requested rank count
        gx = max((d for d in range(2, int(ranks ** 0.5) + 1)
                  if ranks % d == 0), default=0)
        if gx == 0:
            raise SystemExit(
                f"--workload stencil needs --ranks with a gx*gy "
                f"factorization, both factors >= 2 (got {ranks})")
        return make_workload(kind, dims=(gx, ranks // gx),
                             halo_flits=flits, iters=iters)
    if kind == "graph_scatter":
        return make_workload(kind, n_ranks=ranks, flits=flits, iters=iters)
    if kind == "ring_all_reduce":
        return make_workload(kind, n_ranks=ranks, chunk_flits=flits)
    if kind == "recdbl_all_reduce":
        return make_workload(kind, n_ranks=ranks, size_flits=flits)
    return make_workload(kind, n_ranks=ranks, flits_per_pair=flits)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--topo", default="sf", choices=["sf", "df", "ft3"])
    ap.add_argument("--q", type=int, default=5)
    ap.add_argument("--workload", default="ring_all_reduce",
                    choices=["ring_all_reduce", "recdbl_all_reduce",
                             "all_to_all", "stencil", "graph_scatter"])
    ap.add_argument("--ranks", type=int, default=32)
    ap.add_argument("--flits", type=int, default=8,
                    help="per-message flits (chunk/halo/pair size)")
    ap.add_argument("--iters", type=int, default=2)
    ap.add_argument("--mode", default="min",
                    choices=["min", "val", "ugal_l", "ugal_g", "ecmp"])
    ap.add_argument("--placement", default="linear", choices=PLACEMENTS)
    args = ap.parse_args()

    tables = build_tables(args.topo, args.q)
    wl = build_workload(args.workload, args.ranks, args.flits, args.iters)
    print(f"{args.topo}: {tables.n_routers} routers, "
          f"{tables.n_endpoints} endpoints; workload {wl.name} "
          f"({wl.n_messages} messages, {wl.total_flits} flits)")

    cfg = WorkloadSimConfig(mode=args.mode, placement=args.placement)
    result = run_workload(tables, wl, cfg)
    print(summarize(wl, result).table())

    if args.workload == "ring_all_reduce" and result.completed:
        cc = fabric_crosscheck(tables.topo, "all_reduce",
                               args.ranks * args.flits,
                               result.ep_of_rank, result.makespan)
        print(f"FabricModel ring estimate: {cc['estimate_cycles']:.0f} "
              f"cycles (measured/est = {cc['ratio']:.2f}, "
              f"model best = {cc['best_algorithm']})")


if __name__ == "__main__":
    main()
