"""Quickstart: build a Slim Fly, check the paper's headline properties,
and price it against a Dragonfly.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import build_slimfly, moore_bound, slimfly_params
from repro.core.cost import network_cost, network_power
from repro.core.routing import (analytic_channel_load, build_routing,
                                channel_load_uniform, is_deadlock_free)
from repro.core.topologies import build_dragonfly


def main():
    q = 19                                   # the paper's flagship network
    par = slimfly_params(q)
    print(f"Slim Fly q={q}: N_r={par['n_routers']} routers, "
          f"k'={par['kprime']}, p={par['p']}, N={par['n_endpoints']} "
          f"endpoints")

    topo = build_slimfly(q)
    print(f"  diameter          = {topo.diameter()}  (claim: 2)")
    print(f"  avg endpoint hops = {topo.average_endpoint_hops():.3f}")
    mb = moore_bound(par["kprime"], 2)
    print(f"  Moore-bound ratio = {par['n_routers'] / mb:.2%}")

    rt = build_routing(topo)
    avg_l, max_l = channel_load_uniform(rt)
    print(f"  channel load      = {avg_l:.1f} avg / {max_l:.1f} max "
          f"(analytic {analytic_channel_load(par['kprime'], par['n_routers'], par['p']):.1f})")

    paths = [rt.min_path(s, d) for s in range(0, topo.n_routers, 7)
             for d in range(0, topo.n_routers, 11) if s != d]
    print(f"  MIN deadlock-free with 2 VCs: "
          f"{is_deadlock_free(paths, topo.n_routers)}")

    sf_cost = network_cost(topo, router_radix=43)
    sf_pow = network_power(topo, router_radix=43)
    df = build_dragonfly(h=11, a=22, p=11)   # same radix (43)
    df_cost = network_cost(df, router_radix=43)
    df_pow = network_power(df, router_radix=43)
    print(f"  cost/endpoint     = ${sf_cost['per_endpoint']:.0f} "
          f"(DF same radix: ${df_cost['per_endpoint']:.0f}; "
          f"SF saves {1 - sf_cost['per_endpoint']/df_cost['per_endpoint']:.0%})")
    print(f"  power/endpoint    = {sf_pow['per_endpoint_w']:.2f} W "
          f"(DF: {df_pow['per_endpoint_w']:.2f} W)")


if __name__ == "__main__":
    main()
