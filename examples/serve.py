"""Batched serving demo: continuous-batching engine over a reduced config
with the Pallas decode-attention path.

  PYTHONPATH=src python examples/serve.py [--arch gemma2-2b]
"""

import argparse

import jax
import numpy as np

from repro.configs import get, reduced
from repro.models.model import init_params
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    args = ap.parse_args()

    cfg = reduced(get(args.arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(params, cfg, batch_slots=args.slots,
                           max_len=128)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, rng.integers(4, 20)),
                    max_new_tokens=int(rng.integers(5, 15)))
            for i in range(args.requests)]
    done = engine.run(reqs)
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> "
              f"{len(r.out_tokens)} tokens: {r.out_tokens[:8]}...")
    assert len(done) == args.requests
    print(f"served {len(done)} requests on {args.slots} slots "
          f"(continuous batching)")


if __name__ == "__main__":
    main()
