"""Flit-level simulation of Slim Fly routing (paper §V, Fig 6): sweeps
offered load for MIN/VAL/UGAL-L and prints the latency/throughput curve.

Each mode's load curve runs as ONE lane-batched launch
(`sweep_simulate`, DESIGN.md §10): the five rate points share a single
compile instead of paying a Python round-trip per point.

  PYTHONPATH=src python examples/simulate_routing.py [--q 5] [--pattern uniform]
"""

import argparse

from repro.core import build_slimfly
from repro.sim import SimConfig, SimTables, make_traffic, sweep_simulate

LOADS = [0.1, 0.3, 0.5, 0.7, 0.9]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--q", type=int, default=5)
    ap.add_argument("--pattern", default="uniform",
                    choices=["uniform", "shift", "shuffle", "bitrev",
                             "bitcomp", "worstcase_sf"])
    ap.add_argument("--cycles", type=int, default=800)
    args = ap.parse_args()

    tables = SimTables.build(build_slimfly(args.q))
    traffic = make_traffic(tables, args.pattern)
    print(f"SF q={args.q}: {tables.n_endpoints} endpoints, "
          f"{int(traffic.active.sum())} active ({args.pattern})")
    print(f"{'mode':8s} {'offered':>8s} {'accepted':>9s} {'latency':>9s}")
    for mode in ["min", "val", "ugal_l"]:
        results = sweep_simulate(tables, traffic, SimConfig(
            cycles=args.cycles, warmup=args.cycles // 3, mode=mode),
            rates=LOADS)
        for rate, r in zip(LOADS, results):
            print(f"{mode:8s} {rate:8.2f} {r.accepted_load:9.3f} "
                  f"{r.avg_latency:9.2f}")


if __name__ == "__main__":
    main()
