"""Cluster designer: given a target endpoint count and router radix,
enumerate balanced Slim Fly configurations (paper §VII-A library) and
compare cost/power/latency against Dragonfly and fat-tree alternatives.

  PYTHONPATH=src python examples/cluster_design.py --endpoints 10000
"""

import argparse

from repro.core import (build_slimfly, enumerate_slimfly_configs,
                        slimfly_params)
from repro.core.cost import network_cost, network_power
from repro.core.topologies import build_dragonfly, build_fattree3


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--endpoints", type=int, default=10_000)
    args = ap.parse_args()
    N = args.endpoints

    print(f"=== balanced Slim Fly library up to {2*N} endpoints ===")
    lib = enumerate_slimfly_configs(2 * N)
    for c in lib:
        mark = " <-- closest" if abs(c["n_endpoints"] - N) == min(
            abs(x["n_endpoints"] - N) for x in lib) else ""
        print(f"  q={c['q']:3d}  k={c['router_radix']:3d} "
              f"N_r={c['n_routers']:5d}  N={c['n_endpoints']:6d}{mark}")

    best = min(lib, key=lambda c: abs(c["n_endpoints"] - N))
    sf = build_slimfly(best["q"])
    candidates = [("slimfly", sf)]
    h = (best["router_radix"] + 1) // 4
    candidates.append(("dragonfly", build_dragonfly(h=h)))
    candidates.append(("fattree3", build_fattree3(p=best["router_radix"]
                                                  // 2)))

    print(f"\n=== designs near N={N} ===")
    print(f"{'topology':10s} {'N':>7s} {'routers':>8s} {'diam':>5s} "
          f"{'$ / node':>9s} {'W / node':>9s}")
    for name, topo in candidates:
        c = network_cost(topo)
        p = network_power(topo)
        print(f"{name:10s} {topo.n_endpoints:7d} {topo.n_routers:8d} "
              f"{topo.diameter():5d} {c['per_endpoint']:9.0f} "
              f"{p['per_endpoint_w']:9.2f}")


if __name__ == "__main__":
    main()
