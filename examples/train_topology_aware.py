"""End-to-end driver (deliverable b): train a ~100M-param dense LM for a
few hundred steps with the full framework stack — synthetic data pipeline,
AdamW, checkpoint/restart, fault monitor — and report the topology-aware
collective estimate for the gradient all-reduce on a Slim Fly vs Dragonfly
fabric.

  PYTHONPATH=src python examples/train_topology_aware.py \
      [--steps 300] [--d-model 512] [--layers 8]
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import build_slimfly
from repro.core.topologies import build_dragonfly
from repro.data import SyntheticLM
from repro.dist.topology_aware import FabricModel
from repro.launch.faults import FaultMonitor
from repro.models.model import init_params, param_count
from repro.optim import AdamWConfig
from repro.train import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="lm-100m", family="dense", n_layers=args.layers,
        d_model=args.d_model, n_heads=8, n_kv_heads=4,
        d_ff=4 * args.d_model, vocab=32_000, scan_layers=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n = param_count(params)
    print(f"model: {n/1e6:.1f}M params, {args.layers}L x {args.d_model}")

    data = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=7)
    opt_cfg = AdamWConfig(lr_peak=3e-4, warmup_steps=50,
                          total_steps=args.steps)
    tc = TrainConfig(ckpt_dir=args.ckpt_dir, ckpt_every=100, log_every=20)
    monitor = FaultMonitor()

    t0 = time.time()
    params, _, hist = train(cfg, opt_cfg, tc, data, params, args.steps,
                            monitor=monitor)
    dt = time.time() - t0
    losses = [h["loss"] for h in hist]
    print(f"trained {args.steps} steps in {dt:.0f}s "
          f"({args.steps*args.batch*args.seq/dt:.0f} tok/s)")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(improved: {losses[-1] < losses[0]})")
    print(f"stragglers observed: {len(monitor.straggler_events)}")

    # --- the paper's contribution applied to this job's collectives
    grad_bytes = 4.0 * n
    for name, topo in [("slimfly-q7", build_slimfly(7)),
                       ("dragonfly-h3", build_dragonfly(h=3))]:
        fm = FabricModel(topo)
        group = np.arange(0, fm.n_nodes, max(1, fm.n_nodes // 64))[:64]
        est = fm.estimate("all_reduce", grad_bytes, group)
        b = est["best"]
        print(f"DP grad all-reduce on {name:14s}: {b.time_s*1e3:7.2f} ms "
              f"({b.algorithm}; ring would be "
              f"{est['ring'].time_s*1e3:.2f} ms)")


if __name__ == "__main__":
    main()
