"""Fig 1: average endpoint-to-endpoint hop count, SF vs other topologies."""

from repro.core import build_slimfly
from repro.core.topologies import (build_dragonfly, build_fattree3,
                                   build_flattened_butterfly, build_torus)


def run(fast: bool = True):
    rows = []
    qs = [5, 7, 11] if fast else [5, 7, 11, 13, 17, 19]
    for q in qs:
        sf = build_slimfly(q)
        rows.append(dict(name=f"fig1/avg_hops/sf-q{q}", N=sf.n_endpoints,
                         derived=round(sf.average_endpoint_hops(), 4)))
    for h in ([2, 3] if fast else [2, 3, 5, 7]):
        df = build_dragonfly(h=h)
        rows.append(dict(name=f"fig1/avg_hops/df-h{h}", N=df.n_endpoints,
                         derived=round(df.average_endpoint_hops(), 4)))
    for p in ([6, 9] if fast else [6, 9, 14, 22]):
        ft = build_fattree3(p=p)
        rows.append(dict(name=f"fig1/avg_hops/ft3-p{p}", N=ft.n_endpoints,
                         derived=round(ft.average_endpoint_hops(), 4)))
    fb = build_flattened_butterfly(6, 3)
    rows.append(dict(name="fig1/avg_hops/fbf3-c6", N=fb.n_endpoints,
                     derived=round(fb.average_endpoint_hops(), 4)))
    t3 = build_torus(8, 3)
    rows.append(dict(name="fig1/avg_hops/t3d-8", N=t3.n_endpoints,
                     derived=round(t3.average_endpoint_hops(), 4)))
    # headline check: SF lowest
    sf_best = min(r["derived"] for r in rows if "/sf-" in r["name"])
    others = min(r["derived"] for r in rows if "/sf-" not in r["name"])
    rows.append(dict(name="fig1/claim/sf_lowest",
                     derived=int(sf_best < others)))
    return rows
