"""Table IV + Figs 11-13: cost and power per endpoint across topologies."""

from repro.core import build_slimfly
from repro.core.cost import CABLE_MODELS, network_cost, network_power
from repro.core.topologies import (build_dragonfly, build_fattree3,
                                   build_flattened_butterfly,
                                   build_hypercube, build_torus)


def run(fast: bool = True):
    rows = []
    # paper's headline group: N ~ 10k, high radix
    topos = [
        ("sf-q19-k43", build_slimfly(19), 43),
        ("df-h7-k27", build_dragonfly(h=7), None),
        ("df-h11-k43", build_dragonfly(h=11, a=22, p=11), 43),
        ("ft3-k44", build_fattree3(44), None),
        ("fbf3-c10", build_flattened_butterfly(10, 3), None),
    ]
    if not fast:
        topos += [("t3d-22", build_torus(22, 3), None),
                  ("hc-13", build_hypercube(13), None)]
    for name, topo, billed_k in topos:
        c = network_cost(topo, router_radix=billed_k)
        p = network_power(topo, router_radix=billed_k)
        rows.append(dict(name=f"table4/cost_per_node/{name}",
                         N=topo.n_endpoints,
                         electric=c["n_electric"], fiber=c["n_fiber"],
                         derived=round(c["per_endpoint"], 1)))
        rows.append(dict(name=f"table4/power_per_node/{name}",
                         derived=round(p["per_endpoint_w"], 2)))

    # Fig 12/13: alternative cable models shift absolute cost ~1-2% rel.
    sf = build_slimfly(19)
    base = network_cost(sf, cable="fdr10", router_radix=43)["per_endpoint"]
    for cable in ["elpeus10g", "qdr56"]:
        c = network_cost(sf, cable=cable, router_radix=43)["per_endpoint"]
        rows.append(dict(name=f"fig12_13/sf_cost_{cable}",
                         derived=round(c, 1)))
    # headline claim: SF ~25% cheaper than same-radix DF
    df43 = network_cost(build_dragonfly(h=11, a=22, p=11),
                        router_radix=43)["per_endpoint"]
    rows.append(dict(name="table4/claim/sf_vs_df_cost_ratio",
                     derived=round(base / df43, 3)))
    return rows
