"""Closed-loop workload JCT: ring all-reduce / 2D stencil / graph
scatter on SF vs Dragonfly vs fat tree at EQUAL participating-endpoint
counts, MIN vs UGAL (DESIGN.md §7; the paper's §I claim that Slim Fly
wins under HPC workloads, measured as makespan instead of open-loop
latency/throughput).

For ring all-reduce, each row also carries the cycle-calibrated
`FabricModel` estimate ratio (measured / analytic) — the cross-check
that keeps the planning-time model honest against the cycle sim.

Each (fabric, workload, mode) point runs 2 PRNG seeds as lanes of one
lane-batched closed-loop run (`repro.sim.sweep`, DESIGN.md §10) and
reports the mean makespan and seed spread — one compile and one chunk
loop per point regardless of seed count.

fast mode: q=5 Slim Fly, 32 ranks.  REPRO_SMOKE=1: 16 ranks, smaller
messages, single seed (CI pipeline exercise).  REPRO_FULL=1: q=7,
128 ranks, bigger payloads.
"""

import os

import numpy as np

from repro.core import build_slimfly
from repro.core.topologies import build_dragonfly, build_fattree3
from repro.sim import SimTables, sweep_run_workload
from repro.sim.workloads import (
    WorkloadSimConfig,
    fabric_crosscheck,
    graph_scatter,
    ring_all_reduce,
    stencil,
)


def run(fast: bool = True):
    full = os.environ.get("REPRO_FULL", "0") == "1" or not fast
    smoke = os.environ.get("REPRO_SMOKE", "0") == "1" and not full

    if full:
        q, ranks, chunk_flits, halo, scat = 7, 128, 32, 64, 32
        grid = (16, 8)
    elif smoke:
        q, ranks, chunk_flits, halo, scat = 5, 16, 4, 8, 8
        grid = (4, 4)
    else:
        q, ranks, chunk_flits, halo, scat = 5, 32, 8, 16, 16
        grid = (8, 4)

    fabrics = [
        ("sf", SimTables.build(build_slimfly(q)), "min"),
        ("df", SimTables.build(build_dragonfly(h=3 if full else 2)),
         "ugal_l"),
        ("ft3", SimTables.build(build_fattree3(p=6 if full else 4),
                                ecmp=True), "ecmp"),
    ]
    workloads = [
        ring_all_reduce(ranks, chunk_flits),
        stencil(grid, halo, iters=2),
        graph_scatter(ranks, scat, iters=2, seed=0),
    ]

    # UGAL route choice is stochastic: fast/full runs sweep 2 PRNG
    # seeds as lanes of ONE compiled closed-loop run (repro.sim.sweep)
    # and report the mean makespan with its spread; smoke keeps a
    # single seed, exercising the L=1 degenerate path
    seeds = [0] if smoke else [0, 1]

    rows = []
    for tag, tables, mode in fabrics:
        assert tables.n_endpoints >= ranks, (tag, tables.n_endpoints)
        modes = [mode] if (smoke or tag != "sf") else [mode, "ugal_l"]
        for wl in workloads:
            for m in modes:
                res = sweep_run_workload(
                    tables, wl, WorkloadSimConfig(
                        mode=m, chunk=128 if not full else 512),
                    seeds=seeds)
                spans = np.asarray([r.makespan for r in res])
                r = res[0]
                row = dict(
                    name=f"workloads_jct/{tag}/{wl.name}/{m}",
                    derived=float(spans.mean()),
                    bw=round(float(np.mean([x.achieved_bw for x in res])),
                             2),
                    completed=all(x.completed for x in res))
                if len(res) > 1:
                    row["spread"] = round(float(spans.max() - spans.min()),
                                          1)
                if wl.name.startswith("ring_all_reduce") and r.completed:
                    cc = fabric_crosscheck(
                        tables.topo, "all_reduce", ranks * chunk_flits,
                        r.ep_of_rank, r.makespan)
                    row["fabric_ratio"] = round(cc["ratio"], 3)
                rows.append(row)
    return rows
