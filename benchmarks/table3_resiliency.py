"""Table III + §III-D2/D3: GRAPH resiliency under random link failures.

`resilience_sweep` stops at the first fraction whose survival rate hits
0.0, so the returned dict may omit larger fractions; the (fixed)
`max_tolerated_fraction` scans ascending and stops at the first
sub-threshold fraction, which treats that missing tail — and any
non-monotone rebound — as failed.  The ROUTED counterpart (reroute
success / path stretch / JCT inflation) lives in
`benchmarks/faults_sweep.py`.
"""

from repro.core import build_slimfly
from repro.core.resiliency import max_tolerated_fraction, resilience_sweep
from repro.core.topologies import (build_dragonfly, build_fattree3,
                                   build_hypercube, build_torus)


def run(fast: bool = True):
    n_samples = 10 if fast else 30
    topos = [
        ("sf-q7", build_slimfly(7)),
        ("df-h3", build_dragonfly(h=3)),
        ("t3d-5", build_torus(5, 3)),
        ("hc-7", build_hypercube(7)),
    ]
    if not fast:
        topos += [("sf-q11", build_slimfly(11)),
                  ("ft3-p8", build_fattree3(p=8))]
    rows = []
    for metric in (["disconnect"] if fast
                   else ["disconnect", "diameter", "avgpath"]):
        for name, topo in topos:
            sweep = resilience_sweep(topo, metric, n_samples=n_samples,
                                     seed=11)
            rows.append(dict(name=f"table3/{metric}/{name}",
                             N=topo.n_endpoints,
                             derived=max_tolerated_fraction(sweep)))
    return rows
