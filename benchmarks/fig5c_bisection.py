"""Fig 5c: bisection bandwidth (endpoint-normalised), SF via spectral+KL
partitioning, others analytic (paper's own method mix)."""

from repro.core import build_slimfly
from repro.core.bisection import analytic_bisection_bw, bisection_channels
from repro.core.topologies import build_dln


def run(fast: bool = True):
    rows = []
    for q in ([5, 7] if fast else [5, 7, 11, 13, 19]):
        sf = build_slimfly(q)
        cut = bisection_channels(sf, refine_iters=100 if fast else 500)
        # normalise by endpoints: channels crossing / (N/2) endpoints/side
        rows.append(dict(name=f"fig5c/bisect_channels/sf-q{q}",
                         N=sf.n_endpoints, cut=cut,
                         derived=round(cut / (sf.n_endpoints / 2), 4)))
    d = build_dln(128, 4, seed=2)
    cut = bisection_channels(d, refine_iters=100)
    rows.append(dict(name="fig5c/bisect_channels/dln-128",
                     derived=round(cut / (d.n_endpoints / 2), 4)))
    for fam, N, kp, p in [("hypercube", 8192, 13, 1),
                          ("fattree3", 10648, 44, 22),
                          ("dragonfly", 9702, 20, 7),
                          ("torus3d", 10648, 6, 1),
                          ("longhop", 8192, 19, 1)]:
        bw = analytic_bisection_bw(fam, N, kp, p)
        rows.append(dict(name=f"fig5c/bisect_norm/{fam}",
                         derived=round(bw / (N / 2), 4)))
    return rows
