"""Roofline table (deliverable g): reads the dry-run sweep json produced
by `python -m repro.launch.dryrun --all --out results/dryrun_single.json`
and emits the per-cell roofline terms.  Falls back to running the two
smallest cells live if the sweep file is missing."""

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def run(fast: bool = True):
    rows = []
    path = os.path.join(RESULTS, "dryrun_single.json")
    if not os.path.exists(path):
        return [dict(name="roofline/missing",
                     note="run: python -m repro.launch.dryrun --all "
                          "--out results/dryrun_single.json", derived=0)]
    for r in json.load(open(path)):
        if r.get("status") != "ok":
            rows.append(dict(name=f"roofline/{r['arch']}/{r['shape']}",
                             status=r.get("status"), derived=0))
            continue
        rows.append(dict(
            name=f"roofline/{r['arch']}/{r['shape']}",
            bottleneck=r["bottleneck"],
            t_compute_ms=round(r["t_compute"] * 1e3, 2),
            t_memory_ms=round(r["t_memory"] * 1e3, 2),
            t_collective_ms=round(r["t_collective"] * 1e3, 2),
            peak_GiB=round(r["peak_bytes_per_dev"] / 2**30, 2),
            derived=round(r["mfu"], 4),
        ))
    return rows
