"""Sweep-driven collective schedule search (DESIGN.md §13): can the
simulator OPTIMISE a schedule, not just replay it?

`repro.sim.workloads.search.local_search` hill-climbs over emission
genomes (chunk count, path set, path seed, entry order) for a ring
all-reduce on Slim Fly; every generation of candidates is emitted via
`repro.dist.collectives.emit_policy`, lowered to source-routed engine
operands, and scored in ONE lane-batched `sweep_run_policies` launch —
with pinned pad shapes the entire search costs a single compile, so
the figure of merit is schedules scored per second.

Reported per (q, collective): ring-baseline makespan (the unchunked
MIN-path schedule), best-found makespan, speedup (>= 1 by
construction — the baseline rides in generation 0), candidates scored
and the scoring rate.

fast mode: SF q=5 and q=7, 3 generations x 8 lanes.
REPRO_SMOKE=1: q=5 only, 2 generations (CI pipeline exercise).
REPRO_FULL=1: adds q=7 at 16 ranks and more generations.

Run directly (``python -m benchmarks.collective_search``) it also
appends a ``search/q5/allreduce`` entry to BENCH_engine.json
(best-found vs ring-baseline makespan, schedules-scored-per-sec;
REPRO_BENCH_OUT overrides the path — indirect runs never touch the
committed baseline).
"""

import os

import numpy as np

from repro.core import build_slimfly
from repro.core.routing import build_routing
from repro.sim import SimTables
from repro.sim.workloads import local_search
from repro.sim.workloads.search import search_config

KIND = "ring_all_reduce"


def _search_point(q: int, ranks: int, chunk_flits: int,
                  generations: int, lanes: int, max_chunks: int = 4,
                  seed: int = 0):
    topo = build_slimfly(q)
    rt = build_routing(topo, use_pallas=False)
    tables = SimTables.build(topo, rt)
    cfg = search_config(chunk=64, kernel_path="ref")
    return local_search(tables, rt, KIND, ranks, chunk_flits, cfg,
                        generations=generations, lanes=lanes,
                        max_chunks=max_chunks, seed=seed)


def run(fast: bool = True):
    full = os.environ.get("REPRO_FULL", "0") == "1" or not fast
    smoke = os.environ.get("REPRO_SMOKE", "0") == "1" and not full

    if full:
        points = [(5, 8, 16, 4, 8), (7, 16, 16, 4, 8)]
    elif smoke:
        points = [(5, 8, 16, 2, 8)]
    else:
        points = [(5, 8, 16, 3, 8), (7, 12, 16, 2, 8)]

    rows = []
    for q, ranks, chunk_flits, generations, lanes in points:
        res = _search_point(q, ranks, chunk_flits, generations, lanes)
        assert res.best.makespan <= res.baseline.makespan, \
            (res.best, res.baseline)       # baseline rides in gen 0
        rows.append(dict(
            name=f"search/q{q}/allreduce",
            derived=res.best.makespan,
            baseline=res.baseline.makespan,
            speedup=round(res.speedup, 4),
            best=res.best.genome.label(),
            scored=res.n_scored,
            schedules_per_sec=round(res.schedules_per_sec, 2),
            lanes=lanes))
    return rows


def _append_bench_entry(out_path: str) -> None:
    """Time the warm q=5 schedule search (compile amortised away by a
    first run through the shared sweep cache) and append a
    ``search/q5/allreduce`` entry to the BENCH_engine.json trajectory."""
    from repro.bench import bench_callable, load_bench

    topo = build_slimfly(5)
    rt = build_routing(topo, use_pallas=False)
    tables = SimTables.build(topo, rt)
    cfg = search_config(chunk=64, kernel_path="ref")

    res = {}

    def fn():
        res["r"] = local_search(tables, rt, KIND, 8, 16, cfg,
                                generations=3, lanes=8)

    fn()                                  # compile outside the probe
    r = res["r"]
    entry = bench_callable(
        "search/q5/allreduce", fn, repeats=3, measure_memory="rss",
        meta=dict(kind=KIND, ranks=8, lanes=8, generations=3,
                  baseline_makespan=r.baseline.makespan,
                  best_makespan=r.best.makespan,
                  best=r.best.genome.label(),
                  speedup=round(r.speedup, 4),
                  n_scored=res["r"].n_scored,
                  schedules_per_sec=round(r.schedules_per_sec, 2)))

    import json
    try:
        doc = load_bench(out_path)
    except FileNotFoundError:
        doc = {"schema": 1, "suite": "engine_scaling", "backend": "cpu",
               "meta": {}, "entries": {}}
    doc["entries"][entry.name] = entry.to_json()
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# appended search/q5/allreduce to {out_path}: "
          f"best={r.best.makespan} baseline={r.baseline.makespan} "
          f"sched/s={r.schedules_per_sec:.2f}")


def main() -> None:
    from repro.bench import enable_compilation_cache
    enable_compilation_cache()
    for row in run(fast=True):
        extras = {k: v for k, v in row.items()
                  if k not in ("name", "derived")}
        suffix = ";".join(f"{k}={v}" for k, v in extras.items())
        print(f"{row['name']},{row['derived']}"
              + (f" [{suffix}]" if suffix else ""))
    # only a direct invocation may touch the committed baseline, same
    # rule as benchmarks/engine_scaling.py
    out = os.environ.get("REPRO_BENCH_OUT", "BENCH_engine.json")
    _append_bench_entry(out)


if __name__ == "__main__":
    main()
