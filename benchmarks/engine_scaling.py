"""Engine scaling sweep + the persistent perf-regression benchmark.

Sweeps the open-loop flit simulator over paper-relevant Slim Fly sizes
(q = 5 .. 17 fast, + q = 25 under REPRO_FULL) and records steady-state
cycles/sec, compile time, and peak memory per size into
``BENCH_engine.json`` (schema: repro.bench.harness), plus the
lane-batched sweep benchmark: the fig6-style 5-point q=5 load sweep run
three ways —

  - ``per-point jit``: a fresh trace+compile per sweep point (what a
    naive per-point jit pays, and what a sequential loop over distinct
    failure masks pays on the single-lane path by design);
  - ``sequential``: one cached compile, L sequential device launches;
  - ``sweep_simulate``: one compile, ONE lane-batched launch
    (DESIGN.md §10), asserted bit-exact against the sequential loop.

The sweep entry's ``sweep_points_per_sec`` (lanes / batched wall
seconds) joins q=5 cycles/sec as a CI-gated metric.  This file is the
hot-path trajectory across PRs: CI uploads it as an artifact and gates
on both q=5 numbers (``--check-regression``).

Knobs follow the other benchmarks: REPRO_SMOKE=1 shrinks to q in
{5, 7} with short runs (CI / test_benchmarks_smoke); REPRO_FULL=1 (or
--full) extends to q=25; REPRO_CACHE_DIR enables the persistent
compilation cache (cold/warm state is recorded in the json meta).
``--repeats N`` overrides every entry's repeat count (the committed
q=17 entry defaults to 1 — one steady-state run is ~2.5 min).
REPRO_BENCH_OUT overrides the output path; without it, only a DIRECT
`python -m benchmarks.engine_scaling` invocation writes the committed
BENCH_engine.json baseline — runs via `benchmarks.run` or smoke mode
write gitignored BENCH_engine.{local,smoke}.json so the CI gate's
reference can't be clobbered by accident.

CLI:
  python -m benchmarks.engine_scaling              # refresh the baseline
  python -m benchmarks.engine_scaling --check-regression BENCH_engine.json
"""

import argparse
import os
import sys
import time

import numpy as np

from repro.bench import (BenchEntry, bench_callable, check_regression,
                         enable_compilation_cache, load_bench, write_bench)
from repro.core import build_slimfly, slimfly_params
from repro.sim import (SimConfig, SimTables, make_traffic, simulate,
                       sweep_simulate)

GATE_ENTRY = "engine/q5/ugal_l"
GATE_METRIC = "cycles_per_sec"
SWEEP_GATE_ENTRY = "sweep/q5/fig6-5pt"
SWEEP_GATE_METRIC = "sweep_points_per_sec"
# cross-machine gate: the baseline json is written on one machine and
# checked on another (CI runner), so the factor must stay coarse
GATE_FACTOR = float(os.environ.get("REPRO_BENCH_GATE_FACTOR", "2.0"))

SWEEP_RATES = [0.1, 0.3, 0.5, 0.7, 0.9]


def _bench_point(q: int, cycles: int, mode: str = "ugal_l",
                 rate: float = 0.3, repeats: int = 2,
                 measure_memory=True):
    """One steady-state measurement of the compiled open-loop scan."""
    par = slimfly_params(q)
    tables = SimTables.build(build_slimfly(q))
    tr = make_traffic(tables, "uniform")
    state = {"seed": 0, "last": None}

    def call():
        # seed is a traced operand: bumping it exercises the cached
        # executable on fresh inputs without retracing
        cfg = SimConfig(injection_rate=rate, cycles=cycles, warmup=0,
                        mode=mode, seed=state["seed"])
        state["seed"] += 1
        state["last"] = simulate(tables, tr, cfg)

    entry = bench_callable(
        f"engine/q{q}/{mode}", call, repeats=repeats, cycles=cycles,
        measure_memory=measure_memory,
        meta=dict(q=q, n_routers=par["n_routers"],
                  n_endpoints=par["n_endpoints"], kprime=par["kprime"],
                  mode=mode, rate=rate))
    entry.meta["delivered"] = int(state["last"].delivered)
    return entry, state["last"]


def _bench_sweep(q: int = 5, cycles: int = 700, mode: str = "ugal_l",
                 per_point_jit: bool = True, repeats: int = 1):
    """The fig6-style L-point load sweep, lane-batched vs sequential.

    Returns a BenchEntry for the batched run whose extra metrics carry
    the two sequential baselines and the end-to-end speedups; steady
    numbers are the min over `repeats` measurements (the --repeats
    override applies here like every other entry).  The batched
    per-lane results are asserted bit-exact against the sequential
    loop before any number is recorded.
    """
    import dataclasses

    import jax

    from repro.sim import engine as _engine

    L = len(SWEEP_RATES)
    tables = SimTables.build(build_slimfly(q))
    tr = make_traffic(tables, "uniform")
    cfg = SimConfig(cycles=cycles, warmup=cycles // 3, mode=mode)
    cfgs = [dataclasses.replace(cfg, injection_rate=r) for r in SWEEP_RATES]

    # --- baseline A: fresh jit per point — what any naive per-point
    # jit pays, and what a loop over DISTINCT FAILURE MASKS pays on the
    # single-lane path by design (constant tables recompile per mask;
    # DESIGN.md §10).  Caches are cleared so each point really
    # traces + compiles.
    per_point_s = None
    if per_point_jit:
        t0 = time.perf_counter()
        for c in cfgs:
            _engine._OPEN_LOOP_CACHE.clear()
            jax.clear_caches()
            simulate(tables, tr, c)
        per_point_s = time.perf_counter() - t0
        _engine._OPEN_LOOP_CACHE.clear()
        jax.clear_caches()

    # --- baseline B: today's cached sequential loop (one compile, L
    # launches), timed end-to-end including its single compile
    t0 = time.perf_counter()
    seq = [simulate(tables, tr, c) for c in cfgs]
    sequential_s = time.perf_counter() - t0
    seq_walls = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        seq = [simulate(tables, tr, c) for c in cfgs]
        seq_walls.append(time.perf_counter() - t0)
    sequential_steady_s = min(seq_walls)

    # --- lane-batched: one compile, one launch
    t0 = time.perf_counter()
    swept = sweep_simulate(tables, tr, cfg, rates=SWEEP_RATES)
    sweep_s = time.perf_counter() - t0
    sweep_walls = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        swept = sweep_simulate(tables, tr, cfg, rates=SWEEP_RATES)
        sweep_walls.append(time.perf_counter() - t0)
    sweep_steady_s = min(sweep_walls)

    for a, b in zip(swept, seq):
        assert (a.delivered, a.injected, a.avg_latency) == \
            (b.delivered, b.injected, b.avg_latency), \
            "lane-batched sweep diverged from the sequential loop"
        np.testing.assert_array_equal(a.per_cycle_delivered,
                                      b.per_cycle_delivered)

    par = slimfly_params(q)
    extra = {
        "sweep_points_per_sec": L / sweep_steady_s,
        "sweep_e2e_s": sweep_s,
        "sequential_e2e_s": sequential_s,
        "sequential_steady_s": sequential_steady_s,
        "speedup_vs_sequential": sequential_s / sweep_s,
        "speedup_steady": sequential_steady_s / sweep_steady_s,
    }
    if per_point_s is not None:
        extra["per_point_jit_s"] = per_point_s
        extra["speedup_vs_per_point_jit"] = per_point_s / sweep_s
    entry = BenchEntry(
        name=f"sweep/q{q}/fig6-5pt", wall_s=sweep_steady_s,
        wall_mean_s=sum(sweep_walls) / len(sweep_walls),
        compile_s=sweep_s - sweep_steady_s,
        repeats=len(sweep_walls), cycles=cycles * L,
        meta=dict(q=q, lanes=L, rates=SWEEP_RATES, mode=mode,
                  cycles_per_lane=cycles,
                  n_routers=par["n_routers"],
                  n_endpoints=par["n_endpoints"]),
        extra_metrics=extra)
    return entry


def run(fast: bool = True):
    full = os.environ.get("REPRO_FULL", "0") == "1" or not fast
    smoke = os.environ.get("REPRO_SMOKE", "0") == "1" and not full
    cache_state, cache_dir = enable_compilation_cache()
    repeats_override = os.environ.get("REPRO_BENCH_REPEATS")
    # only a DELIBERATE baseline refresh (direct `python -m
    # benchmarks.engine_scaling`, which routes through main()) writes
    # the committed BENCH_engine.json; indirect runs (benchmarks.run,
    # smoke) default to gitignored local files so a routine benchmark
    # sweep on some other machine can never clobber the CI gate's
    # reference numbers
    default_out = ("BENCH_engine.smoke.json" if smoke
                   else "BENCH_engine.local.json")
    out_path = os.environ.get("REPRO_BENCH_OUT", default_out)

    if smoke:
        points = [(5, 250, 2), (7, 250, 1)]
        sweep_cycles = 120
    elif full:
        points = [(5, 2000, 3), (7, 2000, 2), (11, 2000, 2),
                  (17, 4000, 1), (25, 2000, 1)]
        sweep_cycles = 700
    else:
        # acceptance shape: q=17 open loop, >= 2k cycles, in fast mode;
        # the sweep benchmark replays the fig6 SMOKE sweep shape (250
        # cycles/point) — the acceptance workload — while full mode
        # stretches it to 700 cycles/point for a runtime-dominated view
        points = [(5, 2000, 3), (7, 2000, 2), (11, 2000, 2), (17, 2000, 1)]
        sweep_cycles = 250

    entries, rows = [], []
    for q, cycles, repeats in points:
        if repeats_override:
            repeats = int(repeats_override)
        # tracemalloc's hooks would dominate a paper-scale run; beyond
        # q=11 the cheap RSS high-water probe keeps peak_mem_bytes
        # populated at no measurable cost
        entry, res = _bench_point(q, cycles, repeats=repeats,
                                  measure_memory=(True if q <= 11
                                                  else "rss"))
        entries.append(entry)
        rows.append(dict(
            name=f"engine_scaling/q{q}",
            cycles=cycles,
            n_routers=entry.meta["n_routers"],
            n_endpoints=entry.meta["n_endpoints"],
            compile_s=round(entry.compile_s, 2),
            accepted=round(res.accepted_load, 4),
            derived=round(entry.cycles_per_sec, 2)))   # cycles/sec

    # lane-batched sweep benchmark (smoke: skip the per-point-jit
    # baseline — clearing jax caches and recompiling L times is most of
    # a CI minute and the bit-exactness assert still runs)
    sweep_entry = _bench_sweep(
        q=5, cycles=sweep_cycles, per_point_jit=not smoke,
        repeats=int(repeats_override) if repeats_override else 1)
    entries.append(sweep_entry)
    rows.append(dict(
        name="engine_scaling/sweep_q5_fig6",
        lanes=sweep_entry.meta["lanes"],
        sweep_e2e_s=round(sweep_entry.extra_metrics["sweep_e2e_s"], 2),
        sequential_e2e_s=round(
            sweep_entry.extra_metrics["sequential_e2e_s"], 2),
        speedup=round(
            sweep_entry.extra_metrics.get(
                "speedup_vs_per_point_jit",
                sweep_entry.extra_metrics["speedup_vs_sequential"]), 2),
        derived=round(
            sweep_entry.extra_metrics["sweep_points_per_sec"], 3)))

    write_bench(out_path, "engine_scaling", entries,
                extra_meta={"modes": ["ugal_l"],
                            "smoke": smoke, "full": full,
                            "compile_cache": cache_state,
                            "cache_dir": cache_dir})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--repeats", type=int, default=None,
                    help="override the per-entry steady-state repeat "
                         "count (e.g. bump the q=17 default of 1)")
    ap.add_argument("--check-regression", metavar="BASELINE", default=None,
                    help="compare a fresh q=5 run against BASELINE and "
                         "exit 1 on a >GATE_FACTOR regression of "
                         "cycles/sec or sweep points/sec")
    args = ap.parse_args()

    if args.check_regression:
        try:
            baseline = load_bench(args.check_regression)
        except FileNotFoundError:
            # a missing baseline file must not brick CI (same grace as
            # a missing entry) — the sweep step regenerates it
            print(f"no baseline file {args.check_regression}; skipping")
            sys.exit(0)
        enable_compilation_cache()
        entry, _ = _bench_point(5, cycles=300, repeats=3,
                                measure_memory=False)
        ok, msg = check_regression(baseline, GATE_ENTRY, GATE_METRIC,
                                   entry.cycles_per_sec,
                                   factor=GATE_FACTOR,
                                   higher_is_better=True)
        print(msg)
        # points/sec scales with the per-lane cycle count, so the fresh
        # measurement must replay the baseline entry's own cycles
        base_sweep = baseline.get("entries", {}).get(SWEEP_GATE_ENTRY, {})
        sweep_cycles = int(base_sweep.get("meta", {})
                           .get("cycles_per_lane", 700))
        sweep_entry = _bench_sweep(5, cycles=sweep_cycles,
                                   per_point_jit=False)
        ok2, msg2 = check_regression(
            baseline, SWEEP_GATE_ENTRY, SWEEP_GATE_METRIC,
            sweep_entry.extra_metrics[SWEEP_GATE_METRIC],
            factor=GATE_FACTOR, higher_is_better=True)
        print(msg2)
        sys.exit(0 if ok and ok2 else 1)

    if args.full:
        os.environ["REPRO_FULL"] = "1"
    if args.repeats:
        os.environ["REPRO_BENCH_REPEATS"] = str(args.repeats)
    # direct non-smoke CLI invocation = deliberate baseline refresh;
    # smoke runs keep run()'s gitignored default even when direct
    if os.environ.get("REPRO_SMOKE", "0") != "1" or args.full:
        os.environ.setdefault("REPRO_BENCH_OUT", "BENCH_engine.json")
    for row in run(fast=not args.full):
        print(",".join(f"{k}={v}" for k, v in row.items()))


if __name__ == "__main__":
    main()
