"""Engine scaling sweep + the persistent perf-regression benchmark.

Sweeps the open-loop flit simulator over paper-relevant Slim Fly sizes
(q = 5 .. 17 fast, + q = 25 under REPRO_FULL) and records steady-state
cycles/sec, compile time, and peak memory per size into
``BENCH_engine.json`` (schema: repro.bench.harness).  This file is the
hot-path trajectory across PRs: CI uploads it as an artifact and gates
on the q=5 number (``--check-regression``).

Knobs follow the other benchmarks: REPRO_SMOKE=1 shrinks to q in
{5, 7} with short runs (CI / test_benchmarks_smoke); REPRO_FULL=1 (or
--full) extends to q=25.  REPRO_BENCH_OUT overrides the output path;
without it, only a DIRECT `python -m benchmarks.engine_scaling`
invocation writes the committed BENCH_engine.json baseline — runs via
`benchmarks.run` or smoke mode write gitignored
BENCH_engine.{local,smoke}.json so the CI gate's reference can't be
clobbered by accident.

CLI:
  python -m benchmarks.engine_scaling              # refresh the baseline
  python -m benchmarks.engine_scaling --check-regression BENCH_engine.json
"""

import argparse
import os
import sys

from repro.bench import (bench_callable, check_regression, load_bench,
                         write_bench)
from repro.core import build_slimfly, slimfly_params
from repro.sim import SimConfig, SimTables, make_traffic, simulate

GATE_ENTRY = "engine/q5/ugal_l"
GATE_METRIC = "cycles_per_sec"
# cross-machine gate: the baseline json is written on one machine and
# checked on another (CI runner), so the factor must stay coarse
GATE_FACTOR = float(os.environ.get("REPRO_BENCH_GATE_FACTOR", "2.0"))


def _bench_point(q: int, cycles: int, mode: str = "ugal_l",
                 rate: float = 0.3, repeats: int = 2,
                 measure_memory: bool = True):
    """One steady-state measurement of the compiled open-loop scan."""
    par = slimfly_params(q)
    tables = SimTables.build(build_slimfly(q))
    tr = make_traffic(tables, "uniform")
    state = {"seed": 0, "last": None}

    def call():
        # seed is a traced operand: bumping it exercises the cached
        # executable on fresh inputs without retracing
        cfg = SimConfig(injection_rate=rate, cycles=cycles, warmup=0,
                        mode=mode, seed=state["seed"])
        state["seed"] += 1
        state["last"] = simulate(tables, tr, cfg)

    entry = bench_callable(
        f"engine/q{q}/{mode}", call, repeats=repeats, cycles=cycles,
        measure_memory=measure_memory,
        meta=dict(q=q, n_routers=par["n_routers"],
                  n_endpoints=par["n_endpoints"], kprime=par["kprime"],
                  mode=mode, rate=rate))
    entry.meta["delivered"] = int(state["last"].delivered)
    return entry, state["last"]


def run(fast: bool = True):
    full = os.environ.get("REPRO_FULL", "0") == "1" or not fast
    smoke = os.environ.get("REPRO_SMOKE", "0") == "1" and not full
    # only a DELIBERATE baseline refresh (direct `python -m
    # benchmarks.engine_scaling`, which routes through main()) writes
    # the committed BENCH_engine.json; indirect runs (benchmarks.run,
    # smoke) default to gitignored local files so a routine benchmark
    # sweep on some other machine can never clobber the CI gate's
    # reference numbers
    default_out = ("BENCH_engine.smoke.json" if smoke
                   else "BENCH_engine.local.json")
    out_path = os.environ.get("REPRO_BENCH_OUT", default_out)

    if smoke:
        points = [(5, 250, 2), (7, 250, 1)]
    elif full:
        points = [(5, 2000, 3), (7, 2000, 2), (11, 2000, 2),
                  (17, 4000, 1), (25, 2000, 1)]
    else:
        # acceptance shape: q=17 open loop, >= 2k cycles, in fast mode
        points = [(5, 2000, 3), (7, 2000, 2), (11, 2000, 2), (17, 2000, 1)]

    entries, rows = [], []
    for q, cycles, repeats in points:
        entry, res = _bench_point(q, cycles, repeats=repeats,
                                  measure_memory=(q <= 11))
        entries.append(entry)
        rows.append(dict(
            name=f"engine_scaling/q{q}",
            cycles=cycles,
            n_routers=entry.meta["n_routers"],
            n_endpoints=entry.meta["n_endpoints"],
            compile_s=round(entry.compile_s, 2),
            accepted=round(res.accepted_load, 4),
            derived=round(entry.cycles_per_sec, 2)))   # cycles/sec

    write_bench(out_path, "engine_scaling", entries,
                extra_meta={"modes": ["ugal_l"],
                            "smoke": smoke, "full": full})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--check-regression", metavar="BASELINE", default=None,
                    help="compare a fresh q=5 run against BASELINE and "
                         "exit 1 on a >GATE_FACTOR cycles/sec regression")
    args = ap.parse_args()

    if args.check_regression:
        try:
            baseline = load_bench(args.check_regression)
        except FileNotFoundError:
            # a missing baseline file must not brick CI (same grace as
            # a missing entry) — the sweep step regenerates it
            print(f"no baseline file {args.check_regression}; skipping")
            sys.exit(0)
        entry, _ = _bench_point(5, cycles=300, repeats=3,
                                measure_memory=False)
        ok, msg = check_regression(baseline, GATE_ENTRY, GATE_METRIC,
                                   entry.cycles_per_sec,
                                   factor=GATE_FACTOR,
                                   higher_is_better=True)
        print(msg)
        sys.exit(0 if ok else 1)

    if args.full:
        os.environ["REPRO_FULL"] = "1"
    # direct non-smoke CLI invocation = deliberate baseline refresh;
    # smoke runs keep run()'s gitignored default even when direct
    if os.environ.get("REPRO_SMOKE", "0") != "1" or args.full:
        os.environ.setdefault("REPRO_BENCH_OUT", "BENCH_engine.json")
    for row in run(fast=not args.full):
        print(",".join(f"{k}={v}" for k, v in row.items()))


if __name__ == "__main__":
    main()
