"""Benchmark harness — one module per paper table/figure + the framework
roofline.  Prints ``name,us_per_call,derived`` CSV (module wall time is
amortised over its rows), then one machine-parseable ``# SUMMARY``
JSON line with per-module wall time and status, so CI logs show where
smoke time goes.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig6]
"""

import argparse
import json
import sys
import time

MODULES = [
    "fig1_hops",
    "fig5_moore",
    "fig5c_bisection",
    "table3_resiliency",
    "faults_sweep",
    "fig6_perf",
    "workloads_jct",
    "multitenant",
    "fig8_buffers",
    "engine_scaling",
    "table4_cost",
    "topology_collectives",
    "collective_search",
    "roofline_bench",
    "telemetry_export",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale runs (q=19 sims etc.)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    # persistent compilation cache (REPRO_CACHE_DIR knob): must be
    # configured before the first jit of the process; a warm directory
    # turns every unchanged simulator compile into a deserialize
    from repro.bench import enable_compilation_cache
    cache_state, cache_dir = enable_compilation_cache()
    if cache_state != "off":
        print(f"# compilation cache: {cache_state} ({cache_dir})",
              file=sys.stderr)

    print("name,us_per_call,derived")
    failures = 0
    summary = {}
    for modname in MODULES:
        if args.only and args.only not in modname:
            continue
        mod = __import__(f"benchmarks.{modname}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run(fast=not args.full)
        except Exception as e:  # keep the harness going
            print(f"{modname}/ERROR,0,{type(e).__name__}:{e}",
                  file=sys.stdout)
            failures += 1
            summary[modname] = {"wall_s": round(time.time() - t0, 3),
                                "rows": 0, "status": "error",
                                "error": f"{type(e).__name__}: {e}"}
            continue
        wall = time.time() - t0
        summary[modname] = {"wall_s": round(wall, 3), "rows": len(rows),
                            "status": "ok"}
        dt_us = wall * 1e6 / max(len(rows), 1)
        for row in rows:
            extras = {k: v for k, v in row.items()
                      if k not in ("name", "derived")}
            suffix = ";".join(f"{k}={v}" for k, v in extras.items())
            derived = row.get("derived", "")
            if suffix:
                print(f"{row['name']},{dt_us:.0f},{derived} [{suffix}]")
            else:
                print(f"{row['name']},{dt_us:.0f},{derived}")
    # structured per-module wall-time/status trailer, greppable in CI
    # logs: `grep '^# SUMMARY' | sed 's/^# SUMMARY //' | jq .`
    print("# SUMMARY " + json.dumps(
        {"total_wall_s": round(sum(m["wall_s"] for m in summary.values()),
                               3),
         "failures": failures, "modules": summary}, sort_keys=True))
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
