"""Telemetry export benchmark + artifact writer (DESIGN.md §12).

Three things in one module:

  1. the fig6-smoke-shaped q=5 load sweep with COUNTERS ON — all rate
     lanes in one compiled launch — exported as a per-lane channel-load
     heatmap (``TELEMETRY_channel_load.json``);
  2. a small closed-loop collective with full tracing, exported as
     perfetto-compatible Chrome-trace JSON (``TELEMETRY_trace.json``,
     load it at https://ui.perfetto.dev);
  3. the compile-cost ledger: trace/lowering vs XLA-compile seconds for
     the open-loop runner with telemetry off / counters / counters+
     trace, plus steady-state wall time off-vs-on, written to
     ``BENCH_telemetry.json`` beside the engine bench artifact.

Artifacts land in ``$REPRO_TELEMETRY_DIR`` when set, else next to
``$REPRO_BENCH_OUT``, else the working directory.
"""

import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro.bench import (bench_callable, enable_compilation_cache,
                         lowering_breakdown, write_bench)
from repro.core import build_slimfly
from repro.sim import (SimConfig, SimTables, TelemetryConfig, make_traffic,
                       sweep_simulate)
from repro.sim.engine import _open_loop_runner
from repro.sim.telemetry import export
from repro.sim.workloads import WorkloadSimConfig, run_workload
from repro.sim.workloads.ir import ring_all_reduce


def _artifact_dir() -> str:
    d = os.environ.get("REPRO_TELEMETRY_DIR")
    if d:
        os.makedirs(d, exist_ok=True)
        return d
    bench_out = os.environ.get("REPRO_BENCH_OUT")
    if bench_out and os.path.dirname(bench_out):
        return os.path.dirname(bench_out)
    return "."


def _lowering_entry(tables, traffic, cfg, tag):
    """Fresh-trace lowering/compile breakdown of the open-loop runner
    under one telemetry config (its own static_key ⇒ its own trace).
    The initial carry is built the same way simulate() builds it, so
    the lowered signature matches the production launch."""
    from repro.sim import telemetry as tel

    core, fn = _open_loop_runner(tables, traffic, cfg)
    carry0 = (core.init_queues()
              + (jax.random.PRNGKey(cfg.seed),
                 tel.init_state(cfg.telemetry, core)))
    return lowering_breakdown(fn, carry0, jax.numpy.float32(0.5)), tag


def run(fast: bool = True):
    full = os.environ.get("REPRO_FULL", "0") == "1" or not fast
    smoke = os.environ.get("REPRO_SMOKE", "0") == "1" and not full
    enable_compilation_cache()
    out_dir = _artifact_dir()

    q = 19 if full else 5
    cycles, warmup = (3000, 1000) if full else ((250, 80) if smoke
                                                else (700, 250))
    loads = ([0.1, 0.3, 0.5, 0.7, 0.9] if full
             else ([0.5, 0.8] if smoke else [0.1, 0.5, 0.8]))

    tables = SimTables.build(build_slimfly(q))
    traffic = make_traffic(tables, "uniform")
    rows, entries = [], []

    # ---- 1. counters-on fig6-shaped sweep -> per-lane heatmap --------------
    tc = TelemetryConfig(counters=True)
    cfg = SimConfig(cycles=cycles, warmup=warmup, mode="ugal_l",
                    lookahead=6 if full else 4, telemetry=tc)
    t0 = time.time()
    res = sweep_simulate(tables, traffic, cfg, rates=loads)
    sweep_s = time.time() - t0
    heat_path = os.path.join(out_dir, "TELEMETRY_channel_load.json")
    doc = export.write_channel_heatmap(
        heat_path, [r.telemetry for r in res],
        lane_labels=[f"rate={r.offered_load}" for r in res])
    # conservation across every lane: grants == channel forwards +
    # ejections (the drained-run hop identity is asserted in tests)
    for r in res:
        cs = r.telemetry.counters
        assert cs.alloc_grant.sum() == (cs.chan_flits.sum()
                                        + cs.ej_count.sum())
    peak = max(row["load"] for lane in doc["lanes"]
               for row in lane["hottest_channels"])
    rows.append(dict(name=f"telemetry/heatmap_q{q}",
                     lanes=doc["n_lanes"], sweep_s=round(sweep_s, 2),
                     derived=round(peak, 4)))       # hottest channel load

    # ---- 2. traced closed-loop run -> perfetto Chrome trace ----------------
    k, chunk_flits = (16, 128) if not smoke else (8, 64)
    wl = ring_all_reduce(k, chunk_flits // 16)
    wcfg = WorkloadSimConfig(
        mode="ugal_l", placement="linear", chunk=128,
        telemetry=TelemetryConfig(counters=True, trace=True,
                                  trace_sample_shift=0,
                                  trace_capacity=1 << 15))
    wres = run_workload(tables, wl, wcfg)
    trace_path = os.path.join(out_dir, "TELEMETRY_trace.json")
    tdoc = export.write_chrome_trace(
        trace_path, wres.telemetry,
        per_cycle_counter=wres.per_cycle_delivered)
    with open(trace_path) as f:                      # exporter sanity
        loaded = json.load(f)
    assert loaded["traceEvents"], "empty trace"
    rows.append(dict(name="telemetry/trace_ring",
                     events=len(wres.telemetry.events),
                     spans=tdoc["otherData"]["n_spans"],
                     dropped=wres.telemetry.events_dropped,
                     derived=float(tdoc["otherData"]["n_spans"])))

    # ---- 3. compile/lowering tax + steady-state overhead -------------------
    lcfg = SimConfig(cycles=cycles, warmup=warmup, mode="ugal_l")
    variants = [
        ("telemetry_off", lcfg, False),
        ("counters", dataclasses.replace(
            lcfg, telemetry=TelemetryConfig(counters=True)), True),
        ("counters_trace", dataclasses.replace(
            lcfg, telemetry=TelemetryConfig(counters=True, trace=True)),
         True),
    ]
    from repro.sim import simulate
    for tag, vcfg, tel_on in variants:
        bd, _ = _lowering_entry(tables, traffic, vcfg, tag)
        ent = bench_callable(
            f"open_loop_q{q}_{tag}",
            lambda c=vcfg: np.asarray(
                simulate(tables, traffic, c).per_cycle_delivered),
            repeats=1 if smoke else 2, cycles=cycles,
            measure_memory=False, telemetry=tel_on)
        ent.extra_metrics.update(bd)
        entries.append(ent)
        rows.append(dict(name=f"telemetry/lowering_{tag}",
                         trace_lower_s=round(bd["trace_lower_s"], 3),
                         xla_compile_s=round(bd["xla_compile_s"], 3),
                         wall_s=round(ent.wall_s, 3),
                         derived=round(ent.cycles_per_sec, 1)))

    bench_path = os.path.join(out_dir, "BENCH_telemetry.json")
    write_bench(bench_path, "telemetry_export", entries,
                extra_meta={"q": q, "smoke": smoke, "full": full,
                            "artifacts": [heat_path, trace_path]})
    return rows
