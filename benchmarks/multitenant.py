"""Multi-tenant job interference: SF vs Dragonfly vs fat tree at the
matched radix/cost points the JCT benchmark uses (DESIGN.md §11; the
deployment question of Blach et al., arXiv:2310.03742 — how much do
co-located jobs slow each other down on each fabric?).

A fixed mix of 2-4 jobs (ring all-reduce, all-to-all, stencil, graph
scatter) with staggered arrival cycles runs as ONE closed-loop
simulation per (fabric, placement policy) point via
`repro.sim.workloads.jobs.run_jobs`; each job is also run ALONE on its
exact shared-run placement to get the isolated baseline.  Reported per
job: JCT (arrival -> completion, queueing included), JCT slowdown vs
alone, and tail inflation = p99(message latency shared) / p99(alone).
Per (fabric, policy): collective slowdown = mean of per-job slowdowns.

fast mode: q=5 Slim Fly, 3 jobs, pack vs spread vs rack-aware.
REPRO_SMOKE=1: 2 jobs, pack vs spread (CI pipeline exercise).
REPRO_FULL=1: q=7 fabrics, 4 jobs, bigger payloads.

Run directly (``python -m benchmarks.multitenant``) it also times the
steady-state multi-job chunk loop on SF q=5 and appends a
``multitenant/q5`` entry to BENCH_engine.json via `repro.bench`
(REPRO_BENCH_OUT overrides the path; indirect runs never touch the
committed baseline).
"""

import os

import numpy as np

from repro.core import build_slimfly
from repro.core.topologies import build_dragonfly, build_fattree3
from repro.sim import SimTables
from repro.sim.workloads import (
    Job,
    WorkloadSimConfig,
    all_to_all,
    graph_scatter,
    place_jobs,
    ring_all_reduce,
    run_jobs,
    run_workload,
    stencil,
)


def _job_mix(ranks: int, chunk_flits: int, n_jobs: int) -> list:
    """Staggered-arrival tenant mix, sorted by arrival (FIFO order)."""
    jobs = [
        Job("ring", ring_all_reduce(ranks, chunk_flits), arrival=0),
        Job("a2a", all_to_all(max(4, ranks // 2), chunk_flits),
            arrival=24),
    ]
    if n_jobs >= 3:
        jobs.append(Job("stencil", stencil((4, ranks // 4), chunk_flits,
                                           iters=2), arrival=48))
    if n_jobs >= 4:
        jobs.append(Job("scatter", graph_scatter(ranks, chunk_flits,
                                                 iters=2, seed=0),
                        arrival=72))
    return jobs


def _p99(lat: np.ndarray) -> float:
    return float(np.percentile(lat, 99)) if lat.size else float("nan")


def run(fast: bool = True):
    full = os.environ.get("REPRO_FULL", "0") == "1" or not fast
    smoke = os.environ.get("REPRO_SMOKE", "0") == "1" and not full

    if full:
        q, ranks, chunk_flits, n_jobs, chunk = 7, 48, 16, 4, 256
        policies = ("pack", "spread", "rack-aware")
    elif smoke:
        q, ranks, chunk_flits, n_jobs, chunk = 5, 12, 4, 2, 64
        policies = ("pack", "spread")
    else:
        q, ranks, chunk_flits, n_jobs, chunk = 5, 16, 8, 3, 128
        policies = ("pack", "spread", "rack-aware")

    fabrics = [
        ("sf", SimTables.build(build_slimfly(q)), "min"),
        ("df", SimTables.build(build_dragonfly(h=3 if full else 2)),
         "ugal_l"),
        ("ft3", SimTables.build(build_fattree3(p=6 if full else 4),
                                ecmp=True), "ecmp"),
    ]
    jobs = _job_mix(ranks, chunk_flits, n_jobs)

    rows = []
    for tag, tables, mode in fabrics:
        assert tables.n_endpoints >= sum(j.n_ranks for j in jobs), \
            (tag, tables.n_endpoints)
        cfg = WorkloadSimConfig(mode=mode, chunk=chunk)
        for policy in policies:
            placements = place_jobs(tables, jobs, policy)
            shared = run_jobs(tables, jobs, cfg, policy=policy,
                              queue="fifo", placements=placements)

            slowdowns = []
            for j, job in enumerate(jobs):
                # isolated baseline: the same job, alone, on the exact
                # endpoints it got in the shared run
                alone = run_workload(tables, job.workload, cfg,
                                     ep_of_rank=placements[j])
                jr = shared.job(job.name)
                lat_shared = jr.latencies()
                lat_alone = (alone.msg_done[alone.msg_done >= 0]
                             - alone.msg_start[alone.msg_done >= 0]
                             ).astype(np.float64)
                jct_alone = alone.makespan
                slow = (jr.jct / jct_alone if jct_alone > 0
                        else float("inf"))
                slowdowns.append(slow)
                rows.append(dict(
                    name=f"multitenant/{tag}/{policy}/{job.name}",
                    derived=jr.jct,
                    jct_alone=jct_alone,
                    slowdown=round(slow, 3),
                    p99_inflation=round(_p99(lat_shared)
                                        / max(_p99(lat_alone), 1e-9), 3),
                    queue_delay=jr.queue_delay,
                    completed=jr.completed and alone.completed))
            rows.append(dict(
                name=f"multitenant/{tag}/{policy}/collective",
                derived=round(float(np.mean(slowdowns)), 3),
                makespan=shared.makespan,
                completed=shared.completed))
    return rows


def _append_bench_entry(out_path: str) -> None:
    """Time the steady-state SF q=5 multi-job chunk loop and append a
    ``multitenant/q5`` entry to the BENCH_engine.json trajectory."""
    from repro.bench import bench_callable, load_bench

    tables = SimTables.build(build_slimfly(5))
    jobs = _job_mix(16, 8, 3)
    cfg = WorkloadSimConfig(mode="min", chunk=128)
    placements = place_jobs(tables, jobs, "pack")

    res = {}

    def fn():
        res["r"] = run_jobs(tables, jobs, cfg, policy="pack",
                            placements=placements)

    fn()                                  # compile outside the probe
    cycles = res["r"].cycles_run
    entry = bench_callable("multitenant/q5", fn, repeats=3,
                           cycles=cycles, measure_memory="rss",
                           meta=dict(jobs=len(jobs), policy="pack",
                                     mode=cfg.mode,
                                     makespan=res["r"].makespan,
                                     completed=res["r"].completed))

    import json
    try:
        doc = load_bench(out_path)
    except FileNotFoundError:
        doc = {"schema": 1, "suite": "engine_scaling", "backend": "cpu",
               "meta": {}, "entries": {}}
    doc["entries"][entry.name] = entry.to_json()
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# appended multitenant/q5 to {out_path}: "
          f"wall_s={entry.wall_s:.3f} cycles={cycles}")


def main() -> None:
    from repro.bench import enable_compilation_cache
    enable_compilation_cache()
    for row in run(fast=True):
        extras = {k: v for k, v in row.items()
                  if k not in ("name", "derived")}
        suffix = ";".join(f"{k}={v}" for k, v in extras.items())
        print(f"{row['name']},{row['derived']}"
              + (f" [{suffix}]" if suffix else ""))
    # only a direct invocation may touch the committed baseline, same
    # rule as benchmarks/engine_scaling.py
    out = os.environ.get("REPRO_BENCH_OUT", "BENCH_engine.json")
    _append_bench_entry(out)


if __name__ == "__main__":
    main()
