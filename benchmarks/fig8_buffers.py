"""Fig 8a: router buffer-size study (worst-case traffic); Fig 8b-e:
oversubscribed Slim Fly variants.

Knobs (same contract as every other sim benchmark):
  REPRO_SMOKE=1  pipeline-exercising minimum (CI / test_benchmarks_smoke)
  REPRO_FULL=1   paper-scale: q=11 network, long runs, full sweeps
  default fast   q=5, medium runs
"""

import os

from repro.core import build_slimfly
from repro.sim import SimConfig, SimTables, make_traffic, simulate


def run(fast: bool = True):
    full = os.environ.get("REPRO_FULL", "0") == "1" or not fast
    # REPRO_SMOKE=1: pipeline-exercising minimum (CI / test_benchmarks_smoke)
    smoke = os.environ.get("REPRO_SMOKE", "0") == "1" and not full
    q = 11 if full else 5
    cycles, warmup = (2000, 700) if full else (
        (250, 80) if smoke else (600, 200))

    rows = []
    # --- 8a: buffer sizes (total flits/port = 4 VCs * q_net)
    tables = SimTables.build(build_slimfly(q))
    wc = make_traffic(tables, "worstcase_sf")
    buf_sweep = ([4, 64] if smoke else
                 [4, 16, 64] if not full else [2, 4, 8, 16, 32, 64])
    for q_net in buf_sweep:
        r = simulate(tables, wc, SimConfig(
            injection_rate=0.4, cycles=cycles, warmup=warmup,
            mode="ugal_l", q_net=q_net))
        rows.append(dict(name=f"fig8a/buffers/{4*q_net}flits",
                         q=q,
                         latency=round(r.avg_latency, 2),
                         derived=round(r.accepted_load, 4)))

    # --- 8b-e: oversubscription (p > balanced)
    p_sweep = [4, 6] if smoke else [4, 5, 6] if not full else [9, 11, 13, 15]
    for p in p_sweep:
        topo = build_slimfly(q, p=p)
        t = SimTables.build(topo)
        uni = make_traffic(t, "uniform")
        r = simulate(t, uni, SimConfig(injection_rate=0.7, cycles=cycles,
                                       warmup=warmup, mode="min"))
        rows.append(dict(name=f"fig8be/oversub/p{p}",
                         N=topo.n_endpoints,
                         latency=round(r.avg_latency, 2),
                         derived=round(r.accepted_load, 4)))
    return rows
