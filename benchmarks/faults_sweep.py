"""Routed Table III + degraded-mode JCT (§III-D, operational view).

The graph sweep (`table3_resiliency`) asks whether the topology SURVIVES
link failures; this module asks what the ROUTING still delivers on the
degraded fabric (cf. Blach et al. 2023): per failure fraction in 5%
increments, the mean MIN-routing reroute success rate, path stretch and
full-routability survival from `routed_resilience_sweep`; the mean
channel-load inflation at a reference fraction; and the closed-loop
ring-all-reduce JCT inflation (degraded makespan / healthy makespan) on
rebuilt `SimTables`, for SF vs DF vs FT-3.

fast mode: SF q=5 / DF h=2 / FT-3 p=4, fractions 5..25%.
REPRO_SMOKE=1: SF q=5 only, fractions {5%, 10%}, tiny all-reduce (CI).
REPRO_FULL=1: adds SF q=7, fractions to 50%, more samples.
"""

import os

import numpy as np

from repro.core import build_slimfly
from repro.core.resiliency import (failure_edge_sample,
                                   routed_resilience_sweep)
from repro.core.routing import build_routing, routed_resiliency_metrics
from repro.core.topologies import build_dragonfly, build_fattree3
from repro.sim import SimTables, sweep_run_workload
from repro.sim.workloads import WorkloadSimConfig, ring_all_reduce


def _routable_sample(topo, fraction: float, seed: int, tries: int = 20):
    """First sampled mask (seed, seed+1, ...) that keeps every router
    pair reachable, so JCT inflation measures rerouting, not partition."""
    import scipy.sparse as sp
    import scipy.sparse.csgraph as csgraph

    from repro.core import masked_adjacency

    for s in range(seed, seed + tries):
        rng = np.random.default_rng(s)
        fe = failure_edge_sample(topo, fraction, rng)
        adj = masked_adjacency(topo.adj, fe)
        n_comp, _ = csgraph.connected_components(sp.csr_matrix(adj),
                                                 directed=False)
        if n_comp == 1:
            return fe
    return fe                # partitioned fabric: report honestly


def run(fast: bool = True):
    full = os.environ.get("REPRO_FULL", "0") == "1" or not fast
    smoke = os.environ.get("REPRO_SMOKE", "0") == "1" and not full

    if full:
        fractions = np.arange(0.05, 0.55, 0.05)
        n_samples, ranks, chunk_flits, jct_fraction = 10, 32, 8, 0.10
    elif smoke:
        fractions = np.array([0.05, 0.10])
        n_samples, ranks, chunk_flits, jct_fraction = 3, 8, 2, 0.10
    else:
        fractions = np.arange(0.05, 0.30, 0.05)
        n_samples, ranks, chunk_flits, jct_fraction = 5, 16, 4, 0.10

    fabrics = [("sf-q5", build_slimfly(5), "min", False)]
    if not smoke:
        fabrics += [
            ("df-h2", build_dragonfly(h=2), "ugal_l", False),
            ("ft3-p4", build_fattree3(p=4), "ecmp", True),
        ]
    if full:
        fabrics.insert(1, ("sf-q7", build_slimfly(7), "min", False))

    rows = []
    for tag, topo, mode, ecmp in fabrics:
        base_rt = build_routing(topo, use_pallas=False)

        # -- routed Table III: reroute success / stretch / survival -----
        sweep = routed_resilience_sweep(topo, n_samples=n_samples, seed=7,
                                        use_pallas=False,
                                        fractions=fractions)
        for f, point in sweep.items():
            rows.append(dict(
                name=f"faults_sweep/routed/{tag}/f{int(round(f * 100))}",
                derived=round(point["reroute_success"], 4),
                stretch=round(point["mean_stretch"], 3),
                max_stretch=round(point["max_stretch"], 2),
                survival=round(point["survival"], 2)))

        # -- channel-load inflation at the reference fraction -----------
        fe = _routable_sample(topo, jct_fraction, seed=11)
        m = routed_resiliency_metrics(topo, fe, base_rt=base_rt,
                                      use_pallas=False)
        rows.append(dict(
            name=f"faults_sweep/load_inflation/{tag}",
            derived=round(m.load_inflation, 3),
            max_inflation=round(m.max_load_inflation, 3),
            connected=m.connected))

        # -- closed-loop JCT inflation on the degraded fabric -----------
        # healthy and degraded fabrics are two LANES of one batched
        # closed-loop run (repro.sim.sweep, DESIGN.md §10): identical
        # shapes, different table operands — one compile, one chunk
        # loop, instead of a recompile per failure mask
        wl = ring_all_reduce(ranks, chunk_flits)
        cfg = WorkloadSimConfig(mode=mode, chunk=128)
        healthy, degraded = sweep_run_workload(
            [SimTables.build(topo, ecmp=ecmp),
             SimTables.build(topo, ecmp=ecmp, failed_edges=fe)], wl, cfg)
        ratio = (degraded.makespan / healthy.makespan
                 if np.isfinite(healthy.makespan) and healthy.makespan > 0
                 else float("inf"))
        rows.append(dict(
            name=f"faults_sweep/jct/{tag}/{wl.name}/{mode}",
            derived=round(ratio, 3),
            healthy=healthy.makespan,
            degraded=degraded.makespan,
            completed=degraded.completed))
    return rows
