"""Fig 6: latency/throughput of MIN / VAL / UGAL-L / UGAL-G on SF vs
DF-UGAL-L and FT-ANCA(ecmp), under uniform, shift and worst-case traffic.

Load sweeps run through the lane-batched sweep engine
(`repro.sim.sweep`, DESIGN.md §10): all rate points of one
(topology, pattern, mode) are stacked into a lane axis and executed as
one compiled scan — one trace, one launch per curve, instead of a
Python loop over points.

fast mode: q=5 Slim Fly (N=200), short runs — trends, not absolute values.
full mode (REPRO_FULL=1): q=19 (N=10830, the paper's network).
"""

import os

from repro.core import build_slimfly
from repro.core.topologies import build_dragonfly, build_fattree3
from repro.sim import SimConfig, SimTables, make_traffic, sweep_simulate


def run(fast: bool = True):
    full = os.environ.get("REPRO_FULL", "0") == "1" or not fast
    # REPRO_SMOKE=1: pipeline-exercising minimum (CI / test_benchmarks_smoke)
    smoke = os.environ.get("REPRO_SMOKE", "0") == "1" and not full
    q = 19 if full else 5
    cycles, warmup = (3000, 1000) if full else (
        (250, 80) if smoke else (700, 250))

    sf = SimTables.build(build_slimfly(q))
    df = SimTables.build(build_dragonfly(h=7 if full else 2))
    ft = SimTables.build(build_fattree3(p=22 if full else 4), ecmp=True)

    rows = []
    # one Traffic per (tables, pattern): the sweep/runner caches are
    # keyed on the traffic object, so every curve of a pattern reuses
    # one compiled scan
    traffics = {}

    def sweep(tables, pattern, mode, rates, tag):
        """One load curve = one lane-batched launch over `rates`."""
        tr = traffics.get((id(tables), pattern))
        if tr is None:
            tr = traffics[(id(tables), pattern)] = make_traffic(tables,
                                                                pattern)
        res = sweep_simulate(tables, tr, SimConfig(
            cycles=cycles, warmup=warmup, mode=mode,
            lookahead=6 if full else 4), rates=list(rates))
        for rate, r in zip(rates, res):
            rows.append(dict(name=f"fig6/{tag}/{pattern}/{mode}@{rate}",
                             accepted=round(r.accepted_load, 4),
                             latency=round(r.avg_latency, 2),
                             derived=round(r.accepted_load, 4)))
        return res

    # --- 6a uniform: low-load latency + saturation throughput
    loads = ([0.1, 0.3, 0.5, 0.7, 0.9] if full
             else ([0.5] if smoke else [0.1, 0.5, 0.8]))
    for mode in ["min", "val", "ugal_l", "ugal_g"]:
        sweep(sf, "uniform", mode, loads, "sf")
    sweep(df, "uniform", "ugal_l", loads, "df")
    sweep(ft, "uniform", "ecmp", loads, "ft3")

    # --- 6b/6c shift + shuffle
    patterns = ["shift"] if smoke else ["shift", "shuffle"]
    for pattern in patterns:
        for mode in (["min"] if smoke else ["min", "ugal_l"]):
            sweep(sf, pattern, mode, [0.3], "sf")
        if not smoke:
            sweep(df, pattern, "ugal_l", [0.3], "df")

    # --- 6d worst-case
    wc_rates = [0.2] if smoke else [0.2, 0.5]
    for mode in (["ugal_l"] if smoke else ["min", "val", "ugal_l"]):
        sweep(sf, "worstcase_sf", mode, wc_rates, "sf")
    if not smoke:
        sweep(df, "worstcase_df", "ugal_l", wc_rates, "df")
    return rows
