"""Beyond-paper: topology-aware collective cost model — Slim Fly as an ML
training fabric vs Dragonfly / fat tree (repro.dist.topology_aware).

Scores ring vs direct algorithms for the collectives the dry-run emits
(DP all-reduce of gradients, MoE all-to-all) on each fabric.
"""

import numpy as np

from repro.core import build_slimfly
from repro.core.topologies import build_dragonfly, build_fattree3
from repro.dist.topology_aware import FabricModel


def run(fast: bool = True):
    rows = []
    fabrics = [
        ("sf-q7", FabricModel(build_slimfly(7))),
        ("df-h3", FabricModel(build_dragonfly(h=3))),
        ("ft3-p8", FabricModel(build_fattree3(p=8))),
    ]
    group = 64          # a 64-node DP group
    payload = 2 * 2.6e9           # gemma2-2b bf16 gradients
    moe_payload = 64e6            # one MoE layer's a2a shard

    for name, fm in fabrics:
        est = fm.estimate("all_reduce", payload,
                          np.arange(0, fm.n_nodes,
                                    max(1, fm.n_nodes // group))[:group])
        rows.append(dict(name=f"collectives/allreduce_ring/{name}",
                         derived=round(est["ring"].time_s * 1e3, 3)))
        rows.append(dict(name=f"collectives/allreduce_direct/{name}",
                         derived=round(est["direct"].time_s * 1e3, 3)))
        rows.append(dict(name=f"collectives/allreduce_best/{name}",
                         algo=est["best"].algorithm,
                         derived=round(est["best"].time_s * 1e3, 3)))
        a2a = fm.estimate("all_to_all", moe_payload,
                          np.arange(min(16, fm.n_nodes)))
        rows.append(dict(name=f"collectives/moe_a2a_best/{name}",
                         derived=round(a2a["best"].time_s * 1e6, 1)))
    return rows
