"""Fig 5a/5b: proximity to the Moore bound for D=2 and D=3 networks."""

from repro.core import build_slimfly, moore_bound, slimfly_params, valid_q
from repro.core.moore import (bdf_routers, delorme_routers,
                              dragonfly_routers, fbf_routers, mms_routers)


def run(fast: bool = True):
    rows = []
    # ---- D = 2 (Fig 5a): generated SF vs the bound
    for q in ([5, 11, 19] if fast else [5, 7, 11, 13, 17, 19, 25]):
        if valid_q(q) is None:
            continue
        par = slimfly_params(q)
        mb = moore_bound(par["kprime"], 2)
        rows.append(dict(name=f"fig5a/mb_fraction/sf-q{q}",
                         kprime=par["kprime"], n_routers=par["n_routers"],
                         derived=round(par["n_routers"] / mb, 4)))
    # paper's reference point: k'=96 -> 8192 routers vs MB 9217
    frac96 = mms_routers(96.5) / moore_bound(96, 2)
    rows.append(dict(name="fig5a/mb_fraction/sf-k96-analytic",
                     derived=round(8192 / moore_bound(96, 2), 4)))
    # FBF-2 for comparison
    c = 31
    rows.append(dict(name="fig5a/mb_fraction/fbf2-c31",
                     derived=round(c * c / moore_bound(2 * (c - 1), 2), 4)))

    # ---- D = 3 (Fig 5b): analytic fractions at k' = 96 (paper's numbers:
    # DEL 68%, BDF 30%, DF 14%, FBF-3 ~5%)
    k = 96.0
    mb3 = moore_bound(96, 3)
    for nm, f in [("delorme", delorme_routers), ("bdf", bdf_routers),
                  ("dragonfly", dragonfly_routers),
                  ("fbf3", lambda kk: fbf_routers(kk, 3))]:
        rows.append(dict(name=f"fig5b/mb3_fraction/{nm}-k96",
                         derived=round(f(k) / mb3, 4)))
    return rows
